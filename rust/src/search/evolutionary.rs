//! Evolutionary search over traces (paper §4, Figure 7).
//!
//! Population members are validated schedules sampled from the design-space
//! traces ("fork-and-sample"). Each generation mutates sampling decisions,
//! scores candidate batches with the learned cost model, and
//! accepts/rejects via annealed Metropolis–Hastings — evolutionary search
//! as *parallel-chain* MCMC, and the chains here really do run in
//! parallel: the population is partitioned into `chains` independent
//! worker chains executed across up to `threads` OS threads. The top
//! candidates by epsilon-greedy are measured on the hardware oracle `f`
//! through a bounded work queue that overlaps measurement with the next
//! round's fork-and-sample prefetch; measurements update the database and
//! the cost model — MAP estimation of `P(τ|e_0) ∝ exp(−f(g(e_0,τ))) · P(τ)`.
//!
//! # Determinism
//!
//! The thread count is an execution detail, never an input to the math:
//! every chain draws from its own RNG stream derived from `(seed, round,
//! chain index)`, chain results merge in chain order, measurement results
//! fold in submission order, and candidate selection uses a dedicated
//! stream. `(seed, threads = 1)` and `(seed, threads = N)` therefore
//! produce bit-identical tuning results for any pure cost model (both
//! in-tree models qualify).

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Instant;

use crate::cost_model::{CostModel, FeatKey};
use crate::ctx::TuneContext;
use crate::db::{Database, InMemoryDb, TuningRecord};
use crate::schedule::Schedule;
use crate::telemetry::{self, Counter};
use crate::transfer::TransferPool;
use crate::search::parallel::{parallel_map, BoundedQueue, SharedMeasurer};
use crate::search::Measurer;
use crate::tir::{structural_hash, Program};
use crate::trace::replay::replay_fresh;
use crate::trace::{InternedTrace, Trace};
use crate::util::rng::Rng;

/// Evolutionary-search hyperparameters (Appendix A.5 scale, shrunk to
/// simulator-friendly defaults; experiments override `num_trials`).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Population size per round (split across the worker chains).
    pub population: usize,
    /// Mutation generations per round.
    pub generations: usize,
    /// Probability a member is mutated each generation.
    pub mutation_prob: f64,
    /// Total measurement budget.
    pub num_trials: usize,
    /// Measurements per round (top-k by model score).
    pub measure_batch: usize,
    /// Fraction of measured picks taken randomly (epsilon-greedy).
    pub eps_greedy: f64,
    /// Initial MH temperature; annealed multiplicatively per generation.
    pub init_temperature: f64,
    pub anneal: f64,
    /// Independent evolution chains per round (parallel-chain MCMC). The
    /// chain count is part of the algorithm — results depend on it — so
    /// it is fixed here rather than derived from the machine.
    pub chains: usize,
    /// OS threads executing chains and the measurement pipeline. `0`
    /// means auto (all available cores). Purely an execution knob: any
    /// value yields identical results for the same seed.
    pub threads: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            population: 64,
            generations: 4,
            mutation_prob: 0.85,
            num_trials: 64,
            measure_batch: 16,
            eps_greedy: 0.1,
            init_temperature: 0.6,
            anneal: 0.85,
            chains: 4,
            threads: 0,
        }
    }
}

impl SearchConfig {
    /// The OS-thread count to execute with (resolves `0` = auto).
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// Elite traces carried between rounds (and seeded from the database on
/// warm starts).
const ELITE_POOL: usize = 8;
/// Database records replayed into the elite pool on a warm start.
const WARM_TOP_K: usize = 8;
/// Cap on database records replayed into cost-model pretraining samples.
const PRETRAIN_RECORDS: usize = 256;

/// RNG stream kinds, combined with (round, chain) into a stream id. Kept
/// collision-free by construction: see [`stream_id`].
const STREAM_PREFETCH: u64 = 0;
const STREAM_EVOLVE: u64 = 1;
const STREAM_SELECT: u64 = 2;

/// Unique stream id per (round, chain, kind) triple, for [`Rng::for_stream`].
/// Chain packs into 14 bits; beyond that, adjacent rounds would alias.
fn stream_id(round: u64, chain: u64, kind: u64) -> u64 {
    debug_assert!(chain < 16384, "more than 16383 chains aliases RNG streams");
    debug_assert!(kind < 4);
    round * 65536 + chain * 4 + kind
}

/// One time-to-quality sample: how good the best schedule was after how
/// many trials and how much wall-clock. The search records one per
/// successful measurement; `benches/table1_tuning_time.rs` turns the
/// sequence into the time-to-quality curve of `BENCH_table1.json`, and
/// `tune --profile` emits the improving subset as trace instant events.
#[derive(Debug, Clone)]
pub struct QualityPoint {
    pub trials: usize,
    pub best_latency_s: f64,
    /// Wall-clock milliseconds since the tune call started. Timing only
    /// — nothing in the search reads it, so determinism is untouched.
    pub wall_ms: f64,
}

/// Outcome of tuning one task.
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub task: String,
    pub best_latency_s: f64,
    pub best_trace: Trace,
    pub best_prog: Program,
    pub trials: usize,
    /// (trial index, best-so-far latency) — the tuning curve.
    pub curve: Vec<(usize, f64)>,
    /// The curve with wall-clock attached (same indices as `curve`).
    pub quality: Vec<QualityPoint>,
    /// Database records that warm-started this run (0 = cold start).
    pub warm_records: usize,
    /// Cross-target donor candidates re-measured on this run's target
    /// and committed (0 = no transfer pool, or nothing survived the
    /// replay/postproc/dedup gates). Every one of these is a *destination*
    /// measurement — donor latencies are never committed or reported.
    pub transferred_records: usize,
    /// Workload records ignored by the warm start (dedup set, elite
    /// pool, best-so-far) and by pretraining because their `sim_version`
    /// does not match the current simulator model.
    pub stale_skipped: usize,
}

/// One population member: a validated schedule, its canonical id chain
/// in the context's intern arena (dedup identity + feature-cache key),
/// and its model score.
struct Member {
    sch: Schedule,
    interned: InternedTrace,
    score: f64,
}

/// Cached handles into the process-global metrics registry for the
/// search's cumulative families (`search_*`). Fetched once per tune call
/// — the registry mutex is never touched inside the loops — and recorded
/// with relaxed atomic adds, so instrumentation cannot perturb the
/// determinism contract.
struct SearchTelemetry {
    rounds: Arc<Counter>,
    trials: Arc<Counter>,
    predict_batches: Arc<Counter>,
    predict_candidates: Arc<Counter>,
    measure_batches: Arc<Counter>,
    transfer_seeded: Arc<Counter>,
    dedup_trace_hits: Arc<Counter>,
}

impl SearchTelemetry {
    fn from_global() -> SearchTelemetry {
        let g = telemetry::global();
        SearchTelemetry {
            rounds: g.counter("search_rounds_total", "evolutionary search rounds executed"),
            trials: g.counter("search_trials_total", "measurement trials spent"),
            predict_batches: g.counter("search_predict_batches_total", "cost-model predict() batch calls"),
            predict_candidates: g.counter("search_predict_candidates_total", "candidates scored by the cost model"),
            measure_batches: g.counter("search_measure_batches_total", "measurement batches dispatched"),
            transfer_seeded: g.counter(
                "search_transfer_seeded_total",
                "cross-target donor schedules re-measured as warm-start seeds",
            ),
            dedup_trace_hits: g.counter(
                "search_dedup_trace_hits_total",
                "measurement candidates deduplicated by canonical trace ids (no program rehash)",
            ),
        }
    }
}

/// Score `progs` through the model, routing feature extraction through
/// the context's per-canonical-trace cache when it is enabled.
/// `interned` is parallel to `progs`: each entry is the candidate's
/// canonical id chain, keyed under the workload's base-program hash.
/// With the cache disabled this is exactly `model.predict` — the
/// `--no-feature-cache` escape hatch stays byte-identical because
/// cached vectors are element-exact equal to fresh extractions.
fn predict_through_cache(
    model: &dyn CostModel,
    ctx: &TuneContext,
    wl_hash: u64,
    progs: &[&Program],
    interned: &[&InternedTrace],
) -> Vec<f64> {
    match ctx.feature_cache() {
        Some(cache) => {
            let keys: Vec<Option<FeatKey>> =
                interned.iter().map(|it| Some(ctx.feat_key(wl_hash, it))).collect();
            model.predict_cached(progs, &keys, cache)
        }
        None => model.predict(progs),
    }
}

/// Update counterpart of [`predict_through_cache`]: training samples on
/// the commit path populate the cache, which is what guarantees cache
/// hits by round 1 even on a cold database (round-0 measured elites are
/// cached at update time and hit when the next round rescores them).
fn update_through_cache(
    model: &mut dyn CostModel,
    ctx: &TuneContext,
    wl_hash: u64,
    progs: &[&Program],
    latencies_s: &[f64],
    interned: &[InternedTrace],
) {
    match ctx.feature_cache() {
        Some(cache) => {
            let keys: Vec<Option<FeatKey>> =
                interned.iter().map(|it| Some(ctx.feat_key(wl_hash, it))).collect();
            model.update_cached(progs, latencies_s, &keys, cache)
        }
        None => model.update(progs, latencies_s),
    }
}

/// Evolutionary search driver.
pub struct EvolutionarySearch {
    pub cfg: SearchConfig,
}

impl EvolutionarySearch {
    pub fn new(cfg: SearchConfig) -> EvolutionarySearch {
        EvolutionarySearch { cfg }
    }

    /// Tune `prog` within the space generated by `ctx`'s rule set,
    /// measuring with `measurer` and learning with `model`.
    pub fn tune(
        &self,
        prog: &Program,
        ctx: &TuneContext,
        model: &mut dyn CostModel,
        measurer: &mut dyn Measurer,
        seed: u64,
    ) -> TuneResult {
        let designs = ctx.generate(prog, seed);
        let design_traces: Vec<Trace> = designs.into_iter().map(|d| d.trace).collect();
        self.tune_with_designs_warm(prog, ctx, &design_traces, &[], model, measurer, seed)
    }

    /// Like [`Self::tune`] but backed by a tuning database: prior records
    /// for this workload warm-start the search and pretrain the model,
    /// and every measurement is committed back (see [`Self::tune_with_db`]).
    #[allow(clippy::too_many_arguments)]
    pub fn tune_db(
        &self,
        prog: &Program,
        ctx: &TuneContext,
        model: &mut dyn CostModel,
        measurer: &mut dyn Measurer,
        db: &mut dyn Database,
        seed: u64,
    ) -> TuneResult {
        self.tune_db_transfer(prog, ctx, model, measurer, db, None, seed)
    }

    /// [`Self::tune_db`] plus an optional cross-target transfer pool:
    /// compatible donor records from another target seed the search as
    /// re-measured candidates and pretrain the model as discounted
    /// samples (see [`crate::transfer`]). `None` is byte-identical to
    /// [`Self::tune_db`] — the `--no-transfer` escape hatch.
    #[allow(clippy::too_many_arguments)]
    pub fn tune_db_transfer(
        &self,
        prog: &Program,
        ctx: &TuneContext,
        model: &mut dyn CostModel,
        measurer: &mut dyn Measurer,
        db: &mut dyn Database,
        transfer: Option<&TransferPool>,
        seed: u64,
    ) -> TuneResult {
        let designs = ctx.generate(prog, seed);
        let design_traces: Vec<Trace> = designs.into_iter().map(|d| d.trace).collect();
        self.tune_with_db(prog, ctx, &design_traces, &[], model, measurer, db, transfer, seed)
    }

    /// Tune against a precomputed design space (the trace skeletons from a
    /// previous `TuneContext::generate`). This is the §4 execution-
    /// tracing payoff: across task-scheduler rounds the traces are simply
    /// re-executed instead of re-deriving the space — a measurable share
    /// of Table 1's tuning-time advantage.
    pub fn tune_with_designs(
        &self,
        prog: &Program,
        ctx: &TuneContext,
        design_traces: &[Trace],
        model: &mut dyn CostModel,
        measurer: &mut dyn Measurer,
        seed: u64,
    ) -> TuneResult {
        self.tune_with_designs_warm(prog, ctx, design_traces, &[], model, measurer, seed)
    }

    /// Like [`Self::tune_with_designs`] but seeding the elite pool with
    /// previously-found good traces (their *recorded* decisions are
    /// replayed into the initial population). The task scheduler uses this
    /// to carry progress across rounds.
    #[allow(clippy::too_many_arguments)]
    pub fn tune_with_designs_warm(
        &self,
        prog: &Program,
        ctx: &TuneContext,
        design_traces: &[Trace],
        warm_start: &[Trace],
        model: &mut dyn CostModel,
        measurer: &mut dyn Measurer,
        seed: u64,
    ) -> TuneResult {
        // A fresh in-memory database is behaviorally identical to the
        // pre-database search: no warm start, no pretraining, and the
        // committed records die with this call.
        let mut scratch = InMemoryDb::new();
        self.tune_with_db(prog, ctx, design_traces, warm_start, model, measurer, &mut scratch, None, seed)
    }

    /// The full database-backed search (paper §5: search <-> database <->
    /// cost model). On entry the workload is registered, prior records
    /// warm-start the elite pool / best-so-far / dedup set, and the cost
    /// model pretrains on replayed history; every measured candidate
    /// (including validator rejections) is committed back so the next run
    /// — same process or a later session re-opening a
    /// [`crate::db::JsonFileDb`] — resumes instead of restarting.
    ///
    /// Only records stamped with the current [`crate::sim::SIM_VERSION`]
    /// participate in the warm start: a record measured under an older
    /// simulator model is excluded from the elite pool and best-so-far
    /// (its latency is not commensurable) *and* from the dedup set (its
    /// candidate deserves a fresh measurement under the current model —
    /// keeping it deduplicated would freeze the stale latency in
    /// forever). Excluded records are counted in
    /// [`TuneResult::stale_skipped`].
    ///
    /// `transfer` optionally injects another target's records as priors
    /// — see [`crate::transfer`] for the selection rules. Priors are
    /// never truth: donor-derived seed candidates are measured on
    /// *this* run's target (inside the trial budget, serially before
    /// round 0 so the thread count stays irrelevant) before anything is
    /// committed, reported, or allowed to update best-so-far; donor
    /// latencies reach only the cost model, discounted.
    #[allow(clippy::too_many_arguments)]
    pub fn tune_with_db(
        &self,
        prog: &Program,
        ctx: &TuneContext,
        design_traces: &[Trace],
        warm_start: &[Trace],
        model: &mut dyn CostModel,
        measurer: &mut dyn Measurer,
        db: &mut dyn Database,
        transfer: Option<&TransferPool>,
        seed: u64,
    ) -> TuneResult {
        let cfg = &self.cfg;
        assert!(!design_traces.is_empty(), "empty design space");
        let chains = cfg.chains.max(1);
        let threads = cfg.resolved_threads();
        let chain_pop = (cfg.population / chains).max(1);
        // Observation only, all of it: the wall clock feeds QualityPoint
        // records and trace spans, the counters feed /metrics. Nothing
        // below reads any of them back.
        let t0 = Instant::now();
        let tel = SearchTelemetry::from_global();
        let mut tune_span = ctx.span(format!("tune {}", prog.name), "search");

        // Database warm start: prior sim-compatible candidates must not
        // be re-measured (they seed the dedup set), the best recorded
        // traces join the elite pool, and the best record becomes the
        // starting best-so-far — so a warm run can only improve on its
        // history.
        let mut warm_span = ctx.span("warm-start", "search");
        let target_name = measurer.target_name();
        // Provenance stamp for every record this run commits: empty for
        // the default regression objective (field omitted on disk).
        let objective_stamp = model.objective_label().to_string();
        // Hashed once per tune call: workload registration, feature-cache
        // keys, and dedup all share it.
        let wl_hash = structural_hash(prog);
        let wid = db.register_workload(&prog.name, wl_hash, &target_name);
        let all_records = db.records_for(wid);
        let mut stale_skipped = 0usize;
        let mut measured_hashes: HashSet<u64> = HashSet::new();
        // Canonical-trace prefilter over `measured_hashes`: every chain
        // in here replays to a program whose structural hash is already
        // in the measured set (equal trace => equal replayed program),
        // so the selection loop can skip a rediscovered candidate
        // without rehashing its program. Strictly an accelerator —
        // `measured_hashes` stays the source of truth, and the
        // unique-cand_hash invariant on the database is untouched.
        let mut seen_traces: HashSet<InternedTrace> = HashSet::new();
        let mut compat_success: Vec<&TuningRecord> = Vec::new();
        for r in &all_records {
            if r.sim_version != crate::sim::SIM_VERSION {
                stale_skipped += 1;
                continue;
            }
            measured_hashes.insert(r.cand_hash);
            if !r.is_failed() {
                compat_success.push(r);
            }
        }
        // Same criterion as `query_top_k`: ascending best latency,
        // stable sort so commit order breaks ties.
        compat_success.sort_by(|a, b| {
            let (Some(la), Some(lb)) = (a.best_latency(), b.best_latency()) else {
                unreachable!("failed records filtered above");
            };
            la.total_cmp(&lb)
        });
        let db_top: Vec<&TuningRecord> = compat_success.iter().take(WARM_TOP_K).copied().collect();
        let warm_records = db_top.len();
        // Seed best-so-far from the best record that still replays (a
        // schedule-primitive change can invalidate old traces; falling
        // through to the next record keeps the "warm run can only
        // improve on its history" invariant and avoids a best=None panic
        // when the dedup set already covers the whole design space).
        let mut best: Option<(f64, Schedule)> = None;
        for top in &db_top {
            if let (Some(lat), Ok(sch)) = (top.best_latency(), crate::trace::replay(&top.trace, prog, 0)) {
                best = Some((lat, sch));
                break;
            }
        }
        let mut elites: Vec<Trace> = warm_start.to_vec();
        elites.extend(db_top.iter().map(|r| r.trace.clone()));
        elites.truncate(ELITE_POOL);
        drop(db_top);
        // Pretrain the cost model from history so round 1 scores with a
        // fit model instead of the cold neutral prior. Inlined over the
        // compatible records already fetched and sorted above (same gate
        // and order as [`pretrain_cost_model`]) — a second call would
        // re-clone and re-sort the whole record set.
        let mut pt_progs: Vec<Program> = Vec::new();
        let mut pt_lats: Vec<f64> = Vec::new();
        let mut pt_interned: Vec<InternedTrace> = Vec::new();
        for rec in &compat_success {
            if pt_progs.len() >= PRETRAIN_RECORDS {
                break;
            }
            let Some(lat) = rec.best_latency() else {
                continue;
            };
            // Every compatible record's cand_hash is already in
            // `measured_hashes`, so its canonical chain may seed the
            // trace-level prefilter whether or not it still replays.
            let it = ctx.intern_trace(&rec.trace);
            if let Ok(sch) = crate::trace::replay(&rec.trace, prog, 0) {
                pt_progs.push(sch.prog);
                pt_lats.push(lat);
                pt_interned.push(it.clone());
            }
            seen_traces.insert(it);
        }
        if !pt_progs.is_empty() {
            let refs: Vec<&Program> = pt_progs.iter().collect();
            update_through_cache(model, ctx, wl_hash, &refs, &pt_lats, &pt_interned);
        }
        drop(pt_progs);
        drop(compat_success);
        drop(all_records);
        warm_span.arg("warm_records", warm_records as f64);
        warm_span.arg("stale_skipped", stale_skipped as f64);
        drop(warm_span);

        let mut curve = Vec::new();
        let mut quality: Vec<QualityPoint> = Vec::new();
        let mut trials = 0usize;
        let mut round: u64 = 0;

        // Cross-target transfer: inject the pool's priors. Runs strictly
        // serially and before any parallel work, so `(seed, threads=1)
        // == (seed, threads=N)` holds for transfer runs too.
        let mut transferred_records = 0usize;
        if let Some(pool) = transfer.filter(|p| !p.is_empty()) {
            let mut transfer_span = ctx.span("transfer", "search");
            // (a) Feature-space model transfer: donor latencies become
            // discounted training samples.
            pool.pretrain(model, prog);
            // (b) Elite seeding with mandatory destination re-measurement:
            // at most half the trial budget, so seeding can never starve
            // the evolutionary rounds that follow.
            let seed_cap = pool.cfg.max_seeds.min(cfg.num_trials / 2);
            let seeds = pool.seed_schedules(prog, ctx, &measured_hashes, seed_cap);
            let mut progs = Vec::new();
            let mut lats = Vec::new();
            let mut seeded_interned: Vec<InternedTrace> = Vec::new();
            for (sch, cand_hash) in seeds {
                let it = ctx.intern_trace(&sch.trace);
                let lat = measurer.measure(&sch.prog);
                trials += 1;
                tel.trials.inc();
                measured_hashes.insert(cand_hash);
                seen_traces.insert(it.clone());
                db.commit_record(TuningRecord {
                    workload: wid,
                    trace: sch.trace.clone(),
                    latencies: lat.into_iter().collect(),
                    target: target_name.clone(),
                    seed,
                    round: 0,
                    cand_hash,
                    sim_version: crate::sim::SIM_VERSION.to_string(),
                    rule_set: ctx.rule_set().to_string(),
                    objective: objective_stamp.clone(),
                });
                transferred_records += 1;
                // Invalid on this target: recorded (so nothing retries
                // it), but it contributes no latency anywhere.
                let Some(lat) = lat else {
                    continue;
                };
                progs.push(sch.prog.clone());
                lats.push(lat);
                seeded_interned.push(it);
                let better = best.as_ref().map(|(b, _)| lat < *b).unwrap_or(true);
                if better {
                    best = Some((lat, sch.clone()));
                    elites.insert(0, sch.trace.clone());
                    elites.truncate(ELITE_POOL);
                }
                let best_now = best.as_ref().unwrap().0;
                curve.push((trials, best_now));
                quality.push(QualityPoint {
                    trials,
                    best_latency_s: best_now,
                    wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                });
                if better {
                    if let Some(sink) = ctx.trace_sink() {
                        sink.instant(
                            "best-improved",
                            "search",
                            &[("trials", trials as f64), ("best_latency_s", best_now)],
                        );
                    }
                }
            }
            // The destination re-measurements are full-weight samples.
            let prog_refs: Vec<&Program> = progs.iter().collect();
            update_through_cache(model, ctx, wl_hash, &prog_refs, &lats, &seeded_interned);
            tel.transfer_seeded.add(transferred_records as u64);
            transfer_span.arg("transferred_records", transferred_records as f64);
        }

        // Round 0's fork-and-sample happens up front; every later round's
        // is prefetched while the previous round's batch is measuring.
        let mut prefetched =
            Self::prefetch_all(prog, ctx, design_traces, chains, chain_pop, seed, 0, threads);

        while trials < cfg.num_trials {
            let mut round_span = ctx.span(format!("round {round}"), "search");
            tel.rounds.inc();
            // 2+3. Evolve the chains: initialize from elites + prefetched
            // fork-and-samples, then mutate generations with annealed MH
            // acceptance and batched cost-model scoring. Chains execute
            // concurrently and merge in chain order.
            let evolve_span = ctx.span("evolve", "search");
            let fresh = std::mem::take(&mut prefetched);
            let model_ref: &dyn CostModel = &*model;
            let elite_snapshot: &[Trace] = &elites;
            let tel_ref = &tel;
            let evolved: Vec<Vec<Member>> = parallel_map(fresh, threads, |c, fresh_c| {
                self.evolve_chain(
                    prog,
                    ctx,
                    elite_snapshot,
                    fresh_c,
                    model_ref,
                    tel_ref,
                    seed,
                    round,
                    c as u64,
                    chains,
                    wl_hash,
                )
            });
            drop(evolve_span);
            let mut population: Vec<Member> = evolved.into_iter().flatten().collect();
            if population.is_empty() {
                break;
            }

            // 4. Select measurement candidates: top-k by score with
            //    epsilon-greedy randoms, deduplicated against the database.
            //    The sort is stable, so ties keep chain order.
            let mut sel_rng = Rng::for_stream(seed, stream_id(round, 0, STREAM_SELECT));
            population.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
            let batch = cfg.measure_batch.min(cfg.num_trials - trials);
            // (population index, structural hash) — the hash is carried
            // to the commit step so the program is only hashed once.
            let mut picked: Vec<(usize, u64)> = Vec::with_capacity(batch);
            let mut pi = 0;
            while picked.len() < batch && pi < population.len() {
                let idx = if sel_rng.gen_bool(cfg.eps_greedy) {
                    sel_rng.gen_range(population.len())
                } else {
                    pi
                };
                pi += 1;
                if picked.iter().any(|&(i, _)| i == idx) {
                    continue;
                }
                let member = &population[idx];
                // Trace-level prefilter: an identical canonical id chain
                // replays to an identical program, so the structural hash
                // is already known to be measured — skip without
                // rehashing the program.
                if seen_traces.contains(&member.interned) {
                    tel.dedup_trace_hits.inc();
                    continue;
                }
                let h = structural_hash(&member.sch.prog);
                if measured_hashes.contains(&h) {
                    // Remember the chain so equal-trace rediscoveries of
                    // this candidate skip the rehash too.
                    seen_traces.insert(member.interned.clone());
                    continue;
                }
                measured_hashes.insert(h);
                seen_traces.insert(member.interned.clone());
                picked.push((idx, h));
            }

            // 5. Measure the picked batch through the bounded queue while
            //    the next round's fork-and-sample prefetch runs alongside.
            let jobs: Vec<(usize, Program)> = picked
                .iter()
                .enumerate()
                .map(|(slot, &(idx, _))| (slot, population[idx].sch.prog.clone()))
                .collect();
            // Prefetch only if another round can actually run (otherwise
            // the samples would be thrown away on loop exit).
            let another_round = !picked.is_empty() && trials + picked.len() < cfg.num_trials;
            let measure_span = ctx.span("measure+prefetch", "search");
            tel.measure_batches.inc();
            let (lats_by_slot, next_fresh) = Self::measure_and_prefetch(
                jobs,
                measurer,
                prog,
                ctx,
                design_traces,
                chains,
                chain_pop,
                seed,
                round + 1,
                threads,
                another_round,
            );
            drop(measure_span);
            prefetched = next_fresh;

            // 6. Fold results in submission order (serial-identical),
            //    update database / model / elites. Every outcome is
            //    committed — validator rejections persist with empty
            //    latencies so future runs skip them too.
            let commit_span = ctx.span("commit+update", "search");
            let mut progs = Vec::new();
            let mut lats = Vec::new();
            let mut trained_interned: Vec<InternedTrace> = Vec::new();
            for (slot, lat) in lats_by_slot.into_iter().enumerate() {
                let (idx, cand_hash) = picked[slot];
                let member = &population[idx];
                trials += 1;
                tel.trials.inc();
                db.commit_record(TuningRecord {
                    workload: wid,
                    trace: member.sch.trace.clone(),
                    latencies: lat.into_iter().collect(),
                    target: target_name.clone(),
                    seed,
                    round,
                    cand_hash,
                    sim_version: crate::sim::SIM_VERSION.to_string(),
                    rule_set: ctx.rule_set().to_string(),
                    objective: objective_stamp.clone(),
                });
                // Invalid on hardware (e.g. scratchpad overflow) -> skipped,
                // exactly like the paper's validator rejections.
                let Some(lat) = lat else {
                    continue;
                };
                progs.push(member.sch.prog.clone());
                lats.push(lat);
                trained_interned.push(member.interned.clone());
                let better = best.as_ref().map(|(b, _)| lat < *b).unwrap_or(true);
                if better {
                    best = Some((lat, member.sch.clone()));
                    elites.insert(0, member.sch.trace.clone());
                    elites.truncate(ELITE_POOL);
                }
                let best_now = best.as_ref().unwrap().0;
                curve.push((trials, best_now));
                quality.push(QualityPoint {
                    trials,
                    best_latency_s: best_now,
                    wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                });
                if better {
                    if let Some(sink) = ctx.trace_sink() {
                        sink.instant(
                            "best-improved",
                            "search",
                            &[("trials", trials as f64), ("best_latency_s", best_now)],
                        );
                    }
                }
            }
            let prog_refs: Vec<&Program> = progs.iter().collect();
            update_through_cache(model, ctx, wl_hash, &prog_refs, &lats, &trained_interned);
            drop(commit_span);
            round_span.arg("trials_after", trials as f64);
            drop(round_span);
            if picked.is_empty() {
                break; // nothing new to measure; space exhausted
            }
            round += 1;
        }

        let (best_latency_s, best_sch) = best.expect("no valid schedule found");
        tune_span.arg("trials", trials as f64);
        tune_span.arg("best_latency_s", best_latency_s);
        drop(tune_span);
        TuneResult {
            task: prog.name.clone(),
            best_latency_s,
            best_trace: best_sch.trace,
            best_prog: best_sch.prog,
            trials,
            curve,
            quality,
            warm_records,
            transferred_records,
            stale_skipped,
        }
    }

    /// Initialize and evolve one worker chain for one round. All RNG
    /// draws come from this chain's private streams, so the result is a
    /// pure function of `(seed, round, chain)` no matter which OS thread
    /// runs it.
    #[allow(clippy::too_many_arguments)]
    fn evolve_chain(
        &self,
        prog: &Program,
        ctx: &TuneContext,
        elites: &[Trace],
        fresh: Vec<Schedule>,
        model: &dyn CostModel,
        tel: &SearchTelemetry,
        seed: u64,
        round: u64,
        chain: u64,
        chains: usize,
        wl_hash: u64,
    ) -> Vec<Member> {
        let cfg = &self.cfg;
        let _chain_span = ctx.span(format!("chain {chain}"), "search");
        let chain_pop = (cfg.population / chains.max(1)).max(1);
        let mut rng = Rng::for_stream(seed, stream_id(round, chain, STREAM_EVOLVE));

        // Initialize: replay this chain's share of the elite pool
        // (round-robin split so chains do not duplicate each other), then
        // fill with the prefetched fork-and-samples. The quota never
        // truncates to zero while elites exist — small tail-round
        // populations must still carry the warm start forward (the
        // round-robin indexing caps total replays at elites.len()).
        let mut population: Vec<Member> = Vec::with_capacity(chain_pop);
        let elite_quota = if elites.is_empty() { 0 } else { (chain_pop / 4).max(1) };
        let mut ei = chain as usize;
        while population.len() < elite_quota && ei < elites.len() {
            // Elite replays pass the postproc gate like every other
            // population member (with the default verify-integrity
            // pipeline this never rejects a successful replay).
            if let Ok(sch) = crate::trace::replay(&elites[ei], prog, rng.next_u64()) {
                if ctx.postprocess(&sch) {
                    let interned = ctx.intern_trace(&sch.trace);
                    population.push(Member { sch, interned, score: 0.0 });
                }
            }
            ei += chains.max(1);
        }
        for sch in fresh {
            if population.len() >= chain_pop {
                break;
            }
            let interned = ctx.intern_trace(&sch.trace);
            population.push(Member { sch, interned, score: 0.0 });
        }
        if population.is_empty() {
            return population;
        }
        Self::score(&mut population, model, ctx, wl_hash);
        tel.predict_batches.inc();
        tel.predict_candidates.add(population.len() as u64);

        // Evolve with annealed MH acceptance. Each generation proposes
        // mutations for the whole chain, scores them as ONE batch through
        // the cost model, then accepts/rejects member by member.
        let mut temperature = cfg.init_temperature;
        for _gen in 0..cfg.generations {
            let mut proposals: Vec<(usize, Schedule, InternedTrace)> =
                Vec::with_capacity(population.len());
            for (i, m) in population.iter().enumerate() {
                if !rng.gen_bool(cfg.mutation_prob) {
                    continue;
                }
                let mseed = rng.next_u64();
                // The memoized sampling-index list and single-node rewrite
                // are RNG-for-RNG identical to mutating the raw trace.
                if let Some((cand, cand_interned)) =
                    ctx.mutate_interned(&m.interned, &m.sch.trace, prog, &mut rng, mseed)
                {
                    proposals.push((i, cand, cand_interned));
                }
            }
            let cand_progs: Vec<&Program> = proposals.iter().map(|(_, c, _)| &c.prog).collect();
            let cand_interned: Vec<&InternedTrace> =
                proposals.iter().map(|(_, _, it)| it).collect();
            let new_scores = predict_through_cache(model, ctx, wl_hash, &cand_progs, &cand_interned);
            tel.predict_batches.inc();
            tel.predict_candidates.add(cand_progs.len() as u64);
            for ((i, cand, cand_int), new_score) in proposals.into_iter().zip(new_scores) {
                let m = &mut population[i];
                let accept = new_score >= m.score
                    || rng.gen_f64() < ((new_score - m.score) / temperature.max(1e-9)).exp();
                if accept {
                    m.sch = cand;
                    m.interned = cand_int;
                    m.score = new_score;
                }
            }
            temperature *= cfg.anneal;
        }
        population
    }

    /// Fork-and-sample `chain_pop` fresh members per chain for `round`,
    /// across up to `threads` OS threads (chain order preserved).
    #[allow(clippy::too_many_arguments)]
    fn prefetch_all(
        prog: &Program,
        ctx: &TuneContext,
        design_traces: &[Trace],
        chains: usize,
        chain_pop: usize,
        seed: u64,
        round: u64,
        threads: usize,
    ) -> Vec<Vec<Schedule>> {
        parallel_map((0..chains).collect::<Vec<usize>>(), threads, |_, c| {
            Self::prefetch_chain(prog, ctx, design_traces, chain_pop, seed, round, c as u64)
        })
    }

    /// One chain's fresh fork-and-samples for `round`: replay design
    /// traces with fresh sampling decisions, keeping what replays AND
    /// passes the context's postproc pipeline — so `--postprocs
    /// sim-validity` really does reject target-invalid candidates before
    /// a measurement is spent on them, for fresh samples and mutations
    /// alike. Postprocs are pure and draw no RNG, so the filter is
    /// thread-count-invariant; with the default verify-integrity
    /// pipeline it accepts every successful fresh replay (fresh
    /// decisions are drawn on-support), preserving pre-registry
    /// behaviour. Deliberately overprovisions a full `chain_pop` even
    /// though elite replays take some slots — elite replay can fail, and
    /// fresh slack is what keeps the population full when it does.
    #[allow(clippy::too_many_arguments)]
    fn prefetch_chain(
        prog: &Program,
        ctx: &TuneContext,
        design_traces: &[Trace],
        chain_pop: usize,
        seed: u64,
        round: u64,
        chain: u64,
    ) -> Vec<Schedule> {
        let mut rng = Rng::for_stream(seed, stream_id(round, chain, STREAM_PREFETCH));
        let mut out = Vec::with_capacity(chain_pop);
        let mut attempts = 0;
        while out.len() < chain_pop && attempts < chain_pop * 4 {
            attempts += 1;
            let t = &design_traces[rng.gen_range(design_traces.len())];
            if let Ok(sch) = replay_fresh(t, prog, rng.next_u64()) {
                if ctx.postprocess(&sch) {
                    out.push(sch);
                }
            }
        }
        out
    }

    /// Measure a round's picked batch while prefetching the next round's
    /// fork-and-samples. Serial path (`threads <= 1`) runs the identical
    /// algorithm inline; the parallel path feeds one measurement worker
    /// through a bounded queue (the oracle is a single device — the
    /// pipeline win is overlap, not concurrent measurement) and runs the
    /// prefetch on the remaining `threads - 1` workers. `prefetch` is
    /// false on the final round (the result would be thrown away).
    /// Returns latencies indexed by submission slot plus the prefetched
    /// members per chain.
    #[allow(clippy::too_many_arguments)]
    fn measure_and_prefetch(
        jobs: Vec<(usize, Program)>,
        measurer: &mut dyn Measurer,
        prog: &Program,
        ctx: &TuneContext,
        design_traces: &[Trace],
        chains: usize,
        chain_pop: usize,
        seed: u64,
        next_round: u64,
        threads: usize,
        prefetch: bool,
    ) -> (Vec<Option<f64>>, Vec<Vec<Schedule>>) {
        let n_jobs = jobs.len();
        if threads <= 1 {
            let lats = jobs.into_iter().map(|(_, p)| measurer.measure(&p)).collect();
            let fresh = if prefetch {
                Self::prefetch_all(prog, ctx, design_traces, chains, chain_pop, seed, next_round, 1)
            } else {
                Vec::new()
            };
            return (lats, fresh);
        }

        let shared = SharedMeasurer::new(measurer);
        // Capacity covers the whole batch: the producer never blocks, so
        // a panicking measurer propagates through the scope join instead
        // of deadlocking a blocked `push`.
        let queue: BoundedQueue<(usize, Program)> = BoundedQueue::new(n_jobs.max(1));
        let mut fresh: Vec<Vec<Schedule>> = Vec::new();
        std::thread::scope(|s| {
            {
                let queue = &queue;
                let shared = &shared;
                s.spawn(move || {
                    while let Some((slot, p)) = queue.pop() {
                        shared.measure(slot, &p);
                    }
                });
            }
            // The prefetch fans out internally over the remaining threads
            // (its own scope), keeping total thread usage within the cap.
            let prefetch_handle = prefetch.then(|| {
                let inner_threads = threads.saturating_sub(1).max(1);
                s.spawn(move || {
                    Self::prefetch_all(
                        prog,
                        ctx,
                        design_traces,
                        chains,
                        chain_pop,
                        seed,
                        next_round,
                        inner_threads,
                    )
                })
            });
            for job in jobs {
                queue.push(job);
            }
            queue.close();
            if let Some(h) = prefetch_handle {
                fresh = h.join().unwrap();
            }
        });
        // The mutex-guarded ledger is the result channel: fold it back
        // into submission order.
        let mut lats = vec![None; n_jobs];
        for rec in shared.take_ledger() {
            lats[rec.slot] = rec.latency_s;
        }
        (lats, fresh)
    }

    fn score(population: &mut [Member], model: &dyn CostModel, ctx: &TuneContext, wl_hash: u64) {
        let progs: Vec<&Program> = population.iter().map(|m| &m.sch.prog).collect();
        let interned: Vec<&InternedTrace> = population.iter().map(|m| &m.interned).collect();
        let scores = predict_through_cache(model, ctx, wl_hash, &progs, &interned);
        for (m, s) in population.iter_mut().zip(scores) {
            m.score = s;
        }
    }
}

/// Random-search baseline over the same design space (no learning, no
/// evolution): fork-and-sample, measure every sample, keep the best.
pub struct ReplaySearch {
    pub num_trials: usize,
}

impl ReplaySearch {
    pub fn tune(
        &self,
        prog: &Program,
        ctx: &TuneContext,
        measurer: &mut dyn Measurer,
        seed: u64,
    ) -> TuneResult {
        let t0 = Instant::now();
        let mut rng = Rng::seed_from_u64(seed);
        let designs = ctx.generate(prog, seed);
        let traces: Vec<Trace> = designs.iter().map(|d| d.trace.clone()).collect();
        let mut best: Option<(f64, Schedule)> = None;
        let mut curve = Vec::new();
        let mut quality = Vec::new();
        let mut trials = 0;
        let mut attempts = 0;
        while trials < self.num_trials && attempts < self.num_trials * 8 {
            attempts += 1;
            let t = &traces[rng.gen_range(traces.len())];
            let Ok(sch) = replay_fresh(t, prog, rng.next_u64()) else {
                continue;
            };
            trials += 1;
            let Some(lat) = measurer.measure(&sch.prog) else {
                continue;
            };
            if best.as_ref().map(|(b, _)| lat < *b).unwrap_or(true) {
                best = Some((lat, sch));
            }
            let best_now = best.as_ref().unwrap().0;
            curve.push((trials, best_now));
            quality.push(QualityPoint {
                trials,
                best_latency_s: best_now,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
        }
        let (best_latency_s, best_sch) = best.expect("no valid schedule found");
        TuneResult {
            task: prog.name.clone(),
            best_latency_s,
            best_trace: best_sch.trace,
            best_prog: best_sch.prog,
            trials,
            curve,
            quality,
            warm_records: 0,
            transferred_records: 0,
            stale_skipped: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost_model::{GbtCostModel, RandomModel};
    use crate::search::SimMeasurer;
    use crate::sim::{simulate, Target};
    use crate::workloads;

    fn quick_cfg(trials: usize) -> SearchConfig {
        SearchConfig {
            population: 24,
            generations: 3,
            num_trials: trials,
            measure_batch: 8,
            ..SearchConfig::default()
        }
    }

    #[test]
    fn search_improves_over_naive_gmm_cpu() {
        let target = Target::cpu_avx512();
        let prog = workloads::matmul(1, 128, 128, 128);
        let naive = simulate(&prog, &target).unwrap().total_s;
        let ctx = TuneContext::generic(target.clone());
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target);
        let search = EvolutionarySearch::new(quick_cfg(48));
        let r = search.tune(&prog, &ctx, &mut model, &mut measurer, 1);
        assert!(
            r.best_latency_s < naive * 0.2,
            "tuned {} vs naive {naive}",
            r.best_latency_s
        );
        // Curve is monotone non-increasing.
        for w in r.curve.windows(2) {
            assert!(w[1].1 <= w[0].1);
        }
    }

    #[test]
    fn search_works_on_gpu_space() {
        let target = Target::gpu();
        let prog = workloads::matmul(1, 128, 128, 128);
        let naive = simulate(&prog, &target).unwrap().total_s;
        let ctx = TuneContext::generic(target.clone());
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target);
        let search = EvolutionarySearch::new(quick_cfg(48));
        let r = search.tune(&prog, &ctx, &mut model, &mut measurer, 2);
        assert!(r.best_latency_s < naive * 0.05);
    }

    #[test]
    fn best_trace_replays_to_best_prog() {
        let target = Target::cpu_avx512();
        let prog = workloads::fused_dense(64, 128, 64);
        let ctx = TuneContext::generic(target.clone());
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target);
        let search = EvolutionarySearch::new(quick_cfg(32));
        let r = search.tune(&prog, &ctx, &mut model, &mut measurer, 3);
        let replayed = crate::trace::replay(&r.best_trace, &prog, 0).unwrap();
        assert_eq!(
            structural_hash(&replayed.prog),
            structural_hash(&r.best_prog)
        );
    }

    #[test]
    fn gbt_model_beats_random_model_on_average() {
        // The learned model should find at-least-as-good schedules within
        // the same trial budget (averaged over seeds to damp noise).
        let target = Target::cpu_avx512();
        let mk_prog = || workloads::matmul(1, 256, 256, 256);
        let ctx = TuneContext::generic(target.clone());
        let mut sum_gbt = 0.0;
        let mut sum_rand = 0.0;
        for seed in 0..4 {
            let mut measurer = SimMeasurer::new(target.clone());
            let search = EvolutionarySearch::new(quick_cfg(64));
            let mut gbt = GbtCostModel::new();
            sum_gbt += search
                .tune(&mk_prog(), &ctx, &mut gbt, &mut measurer, seed)
                .best_latency_s;
            let mut rnd = RandomModel::new(seed);
            sum_rand += search
                .tune(&mk_prog(), &ctx, &mut rnd, &mut measurer, seed)
                .best_latency_s;
        }
        // Averaged over seeds the learned model must be competitive (the
        // margin allows small-budget noise; at paper-scale budgets the gap
        // widens in the GBT's favor).
        assert!(
            sum_gbt <= sum_rand * 1.35,
            "gbt {sum_gbt} vs random {sum_rand}"
        );
    }

    #[test]
    fn replay_search_also_finds_valid_schedules() {
        let target = Target::cpu_avx512();
        let prog = workloads::matmul(1, 128, 128, 128);
        let naive = simulate(&prog, &target).unwrap().total_s;
        let ctx = TuneContext::generic(target.clone());
        let mut measurer = SimMeasurer::new(target);
        let rs = ReplaySearch { num_trials: 32 };
        let r = rs.tune(&prog, &ctx, &mut measurer, 5);
        assert!(r.best_latency_s < naive);
    }

    #[test]
    fn second_run_warm_starts_from_database() {
        let target = Target::cpu_avx512();
        let prog = workloads::matmul(1, 128, 128, 128);
        let ctx = TuneContext::generic(target.clone());
        let mut db = crate::db::InMemoryDb::new();
        let run = |db: &mut dyn crate::db::Database| {
            let mut model = GbtCostModel::new();
            let mut measurer = SimMeasurer::new(target.clone());
            EvolutionarySearch::new(quick_cfg(32)).tune_db(&prog, &ctx, &mut model, &mut measurer, db, 4)
        };
        let cold = run(&mut db);
        assert_eq!(cold.warm_records, 0);
        assert!(db.num_records() > 0, "measurements were not committed");
        let committed_after_cold = db.num_records();
        let warm = run(&mut db);
        assert!(warm.warm_records > 0, "second run did not see the records");
        // Warm best can only match or improve on the recorded best.
        assert!(warm.best_latency_s <= cold.best_latency_s);
        // Records keep accumulating, and the dedup set prevented
        // re-measuring any candidate committed by the cold run.
        assert!(db.num_records() > committed_after_cold);
        let wid = db.find_workload(structural_hash(&prog), target.name).unwrap();
        let hashes = db.candidate_hashes(wid);
        let unique: std::collections::HashSet<u64> = hashes.iter().copied().collect();
        assert_eq!(unique.len(), hashes.len(), "a candidate was measured twice");
    }

    #[test]
    fn warm_start_survives_even_if_budget_exhausts_instantly() {
        // With the entire (tiny) budget already covered by history, the
        // search must return the recorded best instead of panicking.
        let target = Target::cpu_avx512();
        let prog = workloads::matmul(1, 64, 64, 64);
        let ctx = TuneContext::generic(target.clone());
        let mut db = crate::db::InMemoryDb::new();
        let mut run = |trials: usize, seed: u64| {
            let mut model = GbtCostModel::new();
            let mut measurer = SimMeasurer::new(target.clone());
            EvolutionarySearch::new(quick_cfg(trials)).tune_db(
                &prog,
                &ctx,
                &mut model,
                &mut measurer,
                &mut db,
                seed,
            )
        };
        let first = run(24, 9);
        let resumed = run(2, 9);
        assert!(resumed.best_latency_s <= first.best_latency_s);
        assert!(resumed.warm_records > 0);
    }

    // The thread-count determinism contract is covered by the integration
    // suite in rust/tests/determinism.rs (search, GPU space, scheduler,
    // and repeat-run reproducibility), and warm-start determinism by the
    // same suite's warm_start cases.
}
