//! Trace mutators (paper §4, Figure 7): propose a new variant of a trace
//! by changing one random variable's sampling decision, then validate by
//! replaying. Replay failure = the proposal left the support set and is
//! rejected — the *trace validator*.

use std::collections::HashMap;

use crate::schedule::Schedule;
use crate::tir::Program;
use crate::trace::replay::{replay_with_decisions, Decision};
use crate::trace::{Inst, Trace};
use crate::util::rng::Rng;

/// Divisors of `x` greater than 1.
fn proper_divisors(x: i64) -> Vec<i64> {
    let mut out = Vec::new();
    let mut d = 2;
    while d * d <= x {
        if x % d == 0 {
            out.push(d);
            if d != x / d {
                out.push(x / d);
            }
        }
        d += 1;
    }
    if x > 1 {
        out.push(x);
    }
    out.sort_unstable();
    out
}

/// Propose a mutated decision for the sampling instruction at `idx`.
/// Returns `None` when the instruction has no alternative decision.
pub fn propose(trace: &Trace, idx: usize, rng: &mut Rng) -> Option<Decision> {
    match &trace.insts[idx] {
        // Tile-size transfer: move a divisor from one tile level to another
        // (preserves the factor product, i.e. stays a perfect tile).
        Inst::SamplePerfectTile {
            decision,
            max_innermost,
            ..
        } => {
            let n = decision.len();
            if n < 2 {
                return None;
            }
            for _ in 0..16 {
                let src = rng.gen_range(n);
                let dst = rng.gen_range(n);
                if src == dst || decision[src] <= 1 {
                    continue;
                }
                let divs = proper_divisors(decision[src]);
                if divs.is_empty() {
                    continue;
                }
                let d = *rng.choose(&divs);
                let mut new = decision.clone();
                new[src] /= d;
                new[dst] *= d;
                if *max_innermost > 0 && *new.last().unwrap() > *max_innermost {
                    continue;
                }
                if new != *decision {
                    return Some(Decision::Tile(new));
                }
            }
            None
        }
        // Re-draw a different categorical index, weighted by probs.
        Inst::SampleCategorical {
            candidates,
            probs,
            decision,
            ..
        } => {
            if candidates.len() < 2 {
                return None;
            }
            for _ in 0..16 {
                let i = rng.sample_weighted(probs);
                if i != *decision {
                    return Some(Decision::Categorical(i));
                }
            }
            None
        }
        // Compute-location moves need the candidate set *at that point in
        // the trace*; handled by `mutate` below via prefix replay.
        Inst::SampleComputeLocation { .. } => None,
        _ => None,
    }
}

/// Propose a compute-location move by replaying the prefix of the trace to
/// recover the state-dependent candidate set.
fn propose_location(
    trace: &Trace,
    idx: usize,
    prog: &Program,
    rng: &mut Rng,
) -> Option<Decision> {
    let (block, old) = match &trace.insts[idx] {
        Inst::SampleComputeLocation { block, decision, .. } => (*block, *decision),
        _ => return None,
    };
    // Replay everything before idx to recover the program state.
    let prefix = Trace {
        insts: trace.insts[..idx].to_vec(),
    };
    let sch = crate::trace::replay(&prefix, prog, 0).ok()?;
    let item = sch.block(crate::schedule::BlockRv(block)).ok()?;
    let n = sch.compute_location_candidates(item).len();
    // Candidates: {-1 (root)} ∪ {0..n}; try to find one different from old.
    let mut options: Vec<i64> = vec![-1];
    options.extend(0..n as i64);
    options.retain(|&d| d != old);
    if options.is_empty() {
        return None;
    }
    Some(Decision::Location(*rng.choose(&options)))
}

/// Mutate one sampling decision of `trace` and validate by replay.
/// Returns the new schedule (with its updated trace), or `None` if no
/// proposal was possible or validation rejected it.
pub fn mutate(trace: &Trace, prog: &Program, rng: &mut Rng, seed: u64) -> Option<Schedule> {
    let sampling = trace.sampling_indices();
    if sampling.is_empty() {
        return None;
    }
    // Try a few instruction picks before giving up.
    for _ in 0..4 {
        let idx = *rng.choose(&sampling);
        let proposal = match &trace.insts[idx] {
            Inst::SampleComputeLocation { .. } => propose_location(trace, idx, prog, rng),
            _ => propose(trace, idx, rng),
        };
        let Some(decision) = proposal else { continue };
        let mut overrides = HashMap::new();
        overrides.insert(idx, decision);
        // Validation: replay with the override; off-support decisions fail.
        if let Ok(sch) = replay_with_decisions(trace, prog, seed, &overrides) {
            if sch.prog.check_integrity().is_ok() {
                return Some(sch);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::sim::Target;
    use crate::space::SpaceComposer;
    use crate::tir::structural_hash;
    use crate::trace::FactorArg;
    use crate::workloads;

    fn tiled_matmul(seed: u64) -> (Program, Schedule) {
        let prog = workloads::matmul(1, 64, 64, 64);
        let mut s = Schedule::new(prog.clone(), seed);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        let t = s.sample_perfect_tile(loops[1], 2, 0).unwrap();
        s.split(loops[1], &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])
            .unwrap();
        (prog, s)
    }

    #[test]
    fn tile_transfer_preserves_product() {
        let (_, s) = tiled_matmul(5);
        let mut rng = Rng::seed_from_u64(1);
        let idx = s.trace.sampling_indices()[0];
        let old = match &s.trace.insts[idx] {
            Inst::SamplePerfectTile { decision, .. } => decision.clone(),
            _ => panic!(),
        };
        for _ in 0..10 {
            if let Some(Decision::Tile(new)) = propose(&s.trace, idx, &mut rng) {
                assert_eq!(new.iter().product::<i64>(), old.iter().product::<i64>());
                assert_ne!(new, old);
            }
        }
    }

    #[test]
    fn mutate_produces_structurally_different_valid_schedule() {
        let (prog, s) = tiled_matmul(5);
        let mut rng = Rng::seed_from_u64(2);
        let mut seen_diff = false;
        for i in 0..10 {
            if let Some(m) = mutate(&s.trace, &prog, &mut rng, i) {
                m.prog.check_integrity().unwrap();
                if structural_hash(&m.prog) != structural_hash(&s.prog) {
                    seen_diff = true;
                }
            }
        }
        assert!(seen_diff);
    }

    #[test]
    fn mutate_composed_space_traces() {
        // Mutations over realistic traces from the space composer.
        let prog = workloads::fused_dense(64, 128, 64);
        let composer = SpaceComposer::generic(Target::cpu_avx512());
        let states = composer.generate(&prog, 11);
        let mut rng = Rng::seed_from_u64(3);
        let mut successes = 0;
        for s in &states {
            for i in 0..8 {
                if let Some(m) = mutate(&s.trace, &prog, &mut rng, i) {
                    m.prog.check_integrity().unwrap();
                    successes += 1;
                }
            }
        }
        assert!(successes > 0, "no successful mutations");
    }

    #[test]
    fn empty_trace_cannot_mutate() {
        let prog = workloads::matmul(1, 16, 16, 16);
        let t = Trace::default();
        let mut rng = Rng::seed_from_u64(0);
        assert!(mutate(&t, &prog, &mut rng, 0).is_none());
    }
}
