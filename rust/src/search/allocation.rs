//! Pluggable budget-allocation policies for the task scheduler.
//!
//! The scheduler's allocation loop is a thin driver: it asks an
//! [`AllocationPolicy`] to pick the next task, runs one search round
//! there, and records the outcome in a [`TaskLedger`] — the single
//! source of truth for per-task spend, best-latency history, and
//! saturation. Policies are data, mirroring the rule-registry pattern:
//!
//! * [`Allocation::RoundRobin`] — cycle tasks in order (the ablation
//!   baseline).
//! * [`Allocation::Greedy`] — the historical default: next round to the
//!   task with the largest *weighted best latency* (occurrences ×
//!   latency, the [43]-style criterion), now with priority decay on
//!   non-improving rounds and saturation on dried-up searches so a
//!   plateaued heavy task can no longer absorb the whole tail budget.
//! * [`Allocation::Gradient`] — Ansor-style marginal expected gain: the
//!   recent improvement slope (latency won per trial) × task weight,
//!   plus an exploration bonus for under-sampled tasks.
//!
//! Every policy is a pure function of the ledger, so scheduling stays
//! deterministic and thread-count-invariant: the ledger is built from
//! per-round results that are themselves `(seed, threads)`-invariant.

use crate::search::evolutionary::QualityPoint;

/// Consecutive zero-measurement rounds before a task is marked
/// saturated (its search keeps deduplicating everything it proposes).
const SATURATION_DRY_ROUNDS: usize = 2;

/// Priority multiplier applied by [`Allocation::Greedy`] after a round
/// that measured candidates but failed to improve the task's best.
const GREEDY_DECAY: f64 = 0.5;

/// How many trailing history points the gradient policy's improvement
/// slope looks at (a window, so long-stale progress stops counting).
const GRADIENT_WINDOW: usize = 3;

/// Per-task bookkeeping row of the [`TaskLedger`].
#[derive(Debug, Clone)]
pub struct TaskEntry {
    pub name: String,
    /// Occurrence count of the subgraph in the model.
    pub weight: usize,
    /// Trials charged to this task against the global budget.
    pub spent: usize,
    /// Scheduling rounds this task has received (warmup included).
    pub rounds: usize,
    /// `(cumulative task trials, best-so-far latency)` after each round,
    /// oldest first. Best-so-far is monotone non-increasing.
    pub history: Vec<(usize, f64)>,
    /// Consecutive rounds with zero new measurements.
    pub dry_rounds: usize,
    /// Greedy priority decay: 1.0 = fresh, halved per non-improving
    /// round, reset on improvement.
    pub decay: f64,
    /// The task's search has dried up; policies must stop picking it.
    pub saturated: bool,
}

impl TaskEntry {
    /// Best latency observed so far, if any round measured something.
    pub fn best_latency(&self) -> Option<f64> {
        self.history.last().map(|&(_, l)| l).filter(|l| l.is_finite())
    }

    /// Improvement slope over the trailing window: latency won per trial
    /// (>= 0). Zero until two history points exist or progress stalls.
    pub fn gain_slope(&self) -> f64 {
        let n = self.history.len();
        if n < 2 {
            return 0.0;
        }
        let lo = n.saturating_sub(GRADIENT_WINDOW + 1);
        let (t0, l0) = self.history[lo];
        let (t1, l1) = self.history[n - 1];
        if t1 <= t0 || !l0.is_finite() || !l1.is_finite() {
            return 0.0;
        }
        ((l0 - l1) / (t1 - t0) as f64).max(0.0)
    }
}

/// Scheduler-wide trial accounting: the single source of truth the
/// allocation loop and every policy read. Charging follows the
/// historical convention (a round burns `used.max(1)` budget units so a
/// dry round cannot spin for free), and the ledger asserts the
/// satellite contract `spent <= total_trials + round_trials` whenever
/// the budget is at least one trial per task.
#[derive(Debug, Clone)]
pub struct TaskLedger {
    pub entries: Vec<TaskEntry>,
    pub total_trials: usize,
    pub round_trials: usize,
    pub spent: usize,
    /// Global round counter. Starts at `entries.len()` (warmup rounds
    /// are rounds `0..n`), matching the historical round-seed sequence
    /// `seed + round * 7919`.
    pub next_round: usize,
}

impl TaskLedger {
    pub fn new(tasks: &[(String, usize)], total_trials: usize, round_trials: usize) -> TaskLedger {
        let entries = tasks
            .iter()
            .map(|(name, weight)| TaskEntry {
                name: name.clone(),
                weight: *weight,
                spent: 0,
                rounds: 0,
                history: Vec::new(),
                dry_rounds: 0,
                decay: 1.0,
                saturated: false,
            })
            .collect();
        TaskLedger { entries, total_trials, round_trials, spent: 0, next_round: tasks.len() }
    }

    pub fn remaining(&self) -> usize {
        self.total_trials.saturating_sub(self.spent)
    }

    pub fn all_saturated(&self) -> bool {
        self.entries.iter().all(|e| e.saturated)
    }

    /// Weighted end-to-end latency estimate from the current bests.
    /// Tasks with no measurement yet contribute nothing.
    pub fn e2e_latency(&self) -> f64 {
        self.entries
            .iter()
            .filter_map(|e| e.best_latency().map(|l| l * e.weight as f64))
            .sum()
    }

    /// Record a warmup round: charge the trials, seed the history.
    /// Warmup never decays or saturates — it is the first observation.
    pub fn charge_warmup(&mut self, ti: usize, used: usize, best_latency_s: f64) {
        self.charge(ti, used, best_latency_s);
        let e = &mut self.entries[ti];
        e.dry_rounds = 0;
        e.decay = 1.0;
        e.saturated = false;
    }

    /// Record an allocation round and update the decay/saturation state:
    /// an improving round resets the task's priority, a measured but
    /// non-improving round decays it, and `SATURATION_DRY_ROUNDS`
    /// consecutive zero-measurement rounds retire the task.
    pub fn charge_round(&mut self, ti: usize, used: usize, best_latency_s: f64) {
        let improved = match self.entries[ti].best_latency() {
            Some(old) => best_latency_s.is_finite() && best_latency_s < old,
            None => best_latency_s.is_finite(),
        };
        self.charge(ti, used, best_latency_s);
        let e = &mut self.entries[ti];
        if used == 0 {
            e.dry_rounds += 1;
            if e.dry_rounds >= SATURATION_DRY_ROUNDS {
                e.saturated = true;
            }
        } else {
            e.dry_rounds = 0;
        }
        if improved {
            e.decay = 1.0;
        } else {
            e.decay *= GREEDY_DECAY;
        }
    }

    fn charge(&mut self, ti: usize, used: usize, best_latency_s: f64) {
        let charged = used.max(1);
        self.spent += charged;
        let e = &mut self.entries[ti];
        e.spent += charged;
        e.rounds += 1;
        let best = match e.best_latency() {
            Some(old) if old <= best_latency_s || !best_latency_s.is_finite() => old,
            _ => best_latency_s,
        };
        e.history.push((e.spent, best));
        // Satellite contract: the loop's grant capping keeps total spend
        // within one round of the budget. (A budget under one trial per
        // task inherently overshoots — every task still warms up once.)
        if self.total_trials >= self.entries.len() {
            assert!(
                self.spent <= self.total_trials + self.round_trials,
                "ledger overspent: {} of {} (+{} round slack)",
                self.spent,
                self.total_trials,
                self.round_trials
            );
        }
    }
}

/// A budget-allocation policy: given the ledger, pick the next task to
/// grant a round to, or `None` to stop early (every task saturated).
/// Policies must be deterministic functions of the ledger.
pub trait AllocationPolicy {
    fn pick(&mut self, ledger: &TaskLedger) -> Option<usize>;
    fn name(&self) -> &'static str;
}

/// Cycle through non-saturated tasks in task order.
pub struct RoundRobin;

impl AllocationPolicy for RoundRobin {
    fn pick(&mut self, ledger: &TaskLedger) -> Option<usize> {
        let n = ledger.entries.len();
        (0..n)
            .map(|k| (ledger.next_round + k) % n)
            .find(|&ti| !ledger.entries[ti].saturated)
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// The historical criterion: largest `weight × best latency`, damped by
/// the per-task decay. Never-measured tasks rank first. Ties keep the
/// historical last-max resolution (highest index wins).
pub struct Greedy;

fn pick_max_by_score(ledger: &TaskLedger, score: impl Fn(&TaskEntry) -> f64) -> Option<usize> {
    ledger
        .entries
        .iter()
        .enumerate()
        .filter(|(_, e)| !e.saturated)
        .max_by(|(_, a), (_, b)| score(a).partial_cmp(&score(b)).unwrap())
        .map(|(ti, _)| ti)
}

impl AllocationPolicy for Greedy {
    fn pick(&mut self, ledger: &TaskLedger) -> Option<usize> {
        pick_max_by_score(ledger, |e| match e.best_latency() {
            Some(l) => l * e.weight as f64 * e.decay,
            None => f64::INFINITY,
        })
    }

    fn name(&self) -> &'static str {
        "greedy"
    }
}

/// Ansor-style marginal expected gain: the task whose recent rounds won
/// the most weighted latency per trial, with an exploration bonus for
/// under-sampled tasks. Falls back to the greedy weighted-latency score
/// when no task shows recent improvement (all slopes zero), so the tail
/// budget still goes where the end-to-end time lives.
pub struct GradientGain {
    /// Exploration bonus scale; 0 disables the bonus.
    pub explore: f64,
}

impl Default for GradientGain {
    fn default() -> GradientGain {
        GradientGain { explore: 0.25 }
    }
}

impl AllocationPolicy for GradientGain {
    fn pick(&mut self, ledger: &TaskLedger) -> Option<usize> {
        let score = |e: &TaskEntry| match e.best_latency() {
            None => f64::INFINITY,
            Some(best) => {
                let gain = e.gain_slope() * e.weight as f64;
                // Bonus dimension matches the slope (latency per trial):
                // a cheap, barely-sampled task stays worth probing.
                let bonus = self.explore * e.weight as f64 * best / (e.spent + 1) as f64;
                gain + bonus
            }
        };
        let best_ti = pick_max_by_score(ledger, score)?;
        if score(&ledger.entries[best_ti]) > 0.0 {
            return Some(best_ti);
        }
        // Every live task scored zero (no recent improvement, no
        // exploration bonus): fall back to the greedy criterion.
        pick_max_by_score(ledger, |e| match e.best_latency() {
            Some(l) => l * e.weight as f64 * e.decay,
            None => f64::INFINITY,
        })
    }

    fn name(&self) -> &'static str {
        "gradient"
    }
}

/// Budget-allocation strategy across tasks (the CLI-facing kind).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Allocation {
    RoundRobin,
    /// Weighted-best-latency greedy — the compat default.
    Greedy,
    /// Ansor-style marginal-gain allocation.
    Gradient,
}

impl Default for Allocation {
    fn default() -> Allocation {
        Allocation::Greedy
    }
}

impl Allocation {
    /// Parse a CLI spelling. Returns `None` on unknown names.
    pub fn parse(s: &str) -> Option<Allocation> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => Some(Allocation::RoundRobin),
            "greedy" => Some(Allocation::Greedy),
            "gradient" | "grad" => Some(Allocation::Gradient),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Allocation::RoundRobin => "round-robin",
            Allocation::Greedy => "greedy",
            Allocation::Gradient => "gradient",
        }
    }

    /// Instantiate the policy this kind names.
    pub fn policy(self) -> Box<dyn AllocationPolicy> {
        match self {
            Allocation::RoundRobin => Box::new(RoundRobin),
            Allocation::Greedy => Box::new(Greedy),
            Allocation::Gradient => Box::new(GradientGain::default()),
        }
    }
}

/// One task's share of the budget, for reports and CLI output.
#[derive(Debug, Clone)]
pub struct TaskShare {
    pub name: String,
    pub weight: usize,
    pub trials: usize,
    pub rounds: usize,
    pub best_latency_s: f64,
    pub saturated: bool,
}

/// How a scheduler run spent its budget: per-task shares plus the
/// scheduler-level time-to-quality curve (cumulative trials vs weighted
/// end-to-end latency).
#[derive(Debug, Clone)]
pub struct AllocationReport {
    pub policy: &'static str,
    /// Objective label of the per-task cost models (`mse` / `rank`).
    pub objective: &'static str,
    pub total_trials: usize,
    pub spent: usize,
    /// Allocation rounds granted after warmup.
    pub rounds: usize,
    /// The loop stopped before the budget ran out (all tasks saturated).
    pub early_stop: bool,
    pub per_task: Vec<TaskShare>,
    pub curve: Vec<QualityPoint>,
}

impl AllocationReport {
    pub fn from_ledger(
        policy: &'static str,
        objective: &'static str,
        ledger: &TaskLedger,
        curve: Vec<QualityPoint>,
        early_stop: bool,
    ) -> AllocationReport {
        AllocationReport {
            policy,
            objective,
            total_trials: ledger.total_trials,
            spent: ledger.spent,
            rounds: ledger.next_round - ledger.entries.len(),
            early_stop,
            per_task: ledger
                .entries
                .iter()
                .map(|e| TaskShare {
                    name: e.name.clone(),
                    weight: e.weight,
                    trials: e.spent,
                    rounds: e.rounds,
                    best_latency_s: e.best_latency().unwrap_or(f64::INFINITY),
                    saturated: e.saturated,
                })
                .collect(),
            curve,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger2() -> TaskLedger {
        TaskLedger::new(
            &[("heavy".to_string(), 10), ("light".to_string(), 1)],
            1024,
            16,
        )
    }

    fn warm(ledger: &mut TaskLedger, bests: &[f64]) {
        for (ti, &b) in bests.iter().enumerate() {
            ledger.charge_warmup(ti, 16, b);
        }
    }

    #[test]
    fn parse_and_labels() {
        assert_eq!(Allocation::parse("greedy"), Some(Allocation::Greedy));
        assert_eq!(Allocation::parse("ROUND-ROBIN"), Some(Allocation::RoundRobin));
        assert_eq!(Allocation::parse("rr"), Some(Allocation::RoundRobin));
        assert_eq!(Allocation::parse("gradient"), Some(Allocation::Gradient));
        assert_eq!(Allocation::parse("nope"), None);
        assert_eq!(Allocation::default(), Allocation::Greedy);
        for a in [Allocation::RoundRobin, Allocation::Greedy, Allocation::Gradient] {
            assert_eq!(a.policy().name(), a.label());
        }
    }

    #[test]
    fn greedy_picks_largest_weighted_latency() {
        let mut ledger = ledger2();
        warm(&mut ledger, &[1e-3, 5e-3]);
        // 10 * 1e-3 = 1e-2 > 1 * 5e-3.
        assert_eq!(Greedy.pick(&ledger), Some(0));
        // Never-measured tasks outrank everything.
        let mut l3 = TaskLedger::new(
            &[("a".into(), 1), ("b".into(), 1)],
            1024,
            16,
        );
        l3.charge_warmup(0, 16, 1.0);
        assert_eq!(Greedy.pick(&l3), Some(1));
    }

    #[test]
    fn greedy_decay_prevents_starvation() {
        // Satellite regression: a non-improving heavy task must not
        // absorb more than half of the post-warmup rounds.
        let mut ledger = ledger2();
        warm(&mut ledger, &[1e-3, 5e-4]);
        let mut policy = Greedy;
        let rounds = 24;
        let mut heavy_picks = 0;
        let mut light_best = 5e-4;
        for _ in 0..rounds {
            let ti = policy.pick(&ledger).expect("nothing saturated here");
            if ti == 0 {
                heavy_picks += 1;
                // The heavy task has plateaued: measured but no gain.
                ledger.charge_round(0, 16, 1e-3);
            } else {
                // The light task keeps improving a little every round.
                light_best *= 0.99;
                ledger.charge_round(1, 16, light_best);
            }
            ledger.next_round += 1;
        }
        assert!(
            heavy_picks * 2 <= rounds,
            "plateaued heavy task took {heavy_picks} of {rounds} rounds"
        );
        assert!(heavy_picks >= 1, "decay must not blacklist the heavy task outright");
    }

    #[test]
    fn dry_rounds_saturate_and_stop_the_loop() {
        let mut ledger = ledger2();
        warm(&mut ledger, &[1e-3, 5e-3]);
        // Both searches dry up: zero measurements, repeatedly.
        for _ in 0..SATURATION_DRY_ROUNDS {
            ledger.charge_round(0, 0, 1e-3);
            ledger.charge_round(1, 0, 5e-3);
        }
        assert!(ledger.all_saturated());
        assert_eq!(Greedy.pick(&ledger), None);
        assert_eq!(RoundRobin.pick(&ledger), None);
        assert_eq!(GradientGain::default().pick(&ledger), None);
        // An improving round would have reset the dry counter instead.
        let mut l2 = ledger2();
        warm(&mut l2, &[1e-3, 5e-3]);
        l2.charge_round(0, 0, 1e-3);
        l2.charge_round(0, 8, 9e-4);
        assert!(!l2.entries[0].saturated);
        assert_eq!(l2.entries[0].dry_rounds, 0);
    }

    #[test]
    fn round_robin_cycles_and_skips_saturated() {
        let mut ledger = TaskLedger::new(
            &[("a".into(), 1), ("b".into(), 1), ("c".into(), 1)],
            1024,
            16,
        );
        warm(&mut ledger, &[1.0, 1.0, 1.0]);
        let mut rr = RoundRobin;
        // next_round starts at 3 => 3 % 3 == 0.
        assert_eq!(rr.pick(&ledger), Some(0));
        ledger.next_round += 1;
        assert_eq!(rr.pick(&ledger), Some(1));
        // Saturate task 2: the wheel skips it.
        ledger.entries[2].saturated = true;
        ledger.next_round += 1; // would be task 2's turn
        assert_eq!(rr.pick(&ledger), Some(0));
    }

    #[test]
    fn gradient_prefers_steeper_weighted_slope() {
        let mut ledger = ledger2();
        warm(&mut ledger, &[1e-3, 1e-3]);
        // Heavy task improves 1e-4 over 16 trials; light improves 5e-4.
        ledger.charge_round(0, 16, 9e-4);
        ledger.charge_round(1, 16, 5e-4);
        // Weighted slopes: 10 * (1e-4/16) ≈ 6.3e-5 vs 1 * (5e-4/16) ≈ 3.1e-5.
        assert_eq!(GradientGain { explore: 0.0 }.pick(&ledger), Some(0));
        // Stall the heavy task long enough for its window to forget the
        // old gain; the still-improving light task takes over.
        for _ in 0..(GRADIENT_WINDOW + 1) {
            ledger.charge_round(0, 16, 9e-4);
        }
        ledger.charge_round(1, 16, 4.5e-4);
        assert_eq!(GradientGain { explore: 0.0 }.pick(&ledger), Some(1));
    }

    #[test]
    fn gradient_zero_slope_falls_back_to_greedy() {
        let mut ledger = ledger2();
        warm(&mut ledger, &[1e-3, 5e-3]);
        // No allocation rounds yet: every slope is zero. With the bonus
        // disabled the policy must fall back to weighted-latency greedy.
        assert_eq!(GradientGain { explore: 0.0 }.pick(&ledger), Greedy.pick(&ledger));
        // The exploration bonus instead probes the under-sampled task.
        let mut l2 = ledger2();
        l2.charge_warmup(0, 64, 1e-3);
        l2.charge_warmup(1, 4, 1e-3);
        let pick = GradientGain { explore: 0.5 }.pick(&l2);
        assert_eq!(pick, Some(1), "bonus must favour the barely-sampled task");
    }

    #[test]
    fn ledger_charges_like_the_historical_loop() {
        let mut ledger = ledger2();
        // A dry round still burns one budget unit (no free spinning).
        ledger.charge_warmup(0, 0, f64::INFINITY);
        assert_eq!(ledger.spent, 1);
        assert_eq!(ledger.entries[0].best_latency(), None);
        ledger.charge_warmup(1, 16, 2e-3);
        assert_eq!(ledger.spent, 17);
        // Best-so-far is monotone: a worse later round cannot regress it.
        ledger.charge_round(1, 16, 9e-3);
        assert_eq!(ledger.entries[1].best_latency(), Some(2e-3));
        assert_eq!(ledger.entries[1].spent, 32);
        assert!((ledger.e2e_latency() - 2e-3).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "ledger overspent")]
    fn ledger_asserts_budget_contract() {
        let mut ledger = TaskLedger::new(&[("a".into(), 1)], 8, 4);
        // Charging far past total + round_trials must trip the assert.
        ledger.charge_warmup(0, 8, 1e-3);
        ledger.charge_round(0, 8, 1e-3);
    }

    #[test]
    fn report_summarizes_ledger() {
        let mut ledger = ledger2();
        warm(&mut ledger, &[1e-3, 5e-3]);
        ledger.charge_round(0, 16, 8e-4);
        ledger.next_round += 1;
        let report = AllocationReport::from_ledger("greedy", "mse", &ledger, Vec::new(), false);
        assert_eq!(report.policy, "greedy");
        assert_eq!(report.rounds, 1);
        assert_eq!(report.spent, 48);
        assert_eq!(report.per_task.len(), 2);
        assert_eq!(report.per_task[0].trials, 32);
        assert!((report.per_task[0].best_latency_s - 8e-4).abs() < 1e-12);
        assert!(!report.early_stop);
    }
}
