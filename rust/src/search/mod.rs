//! Learning-driven search (paper §4): evolutionary search over traces with
//! annealed Metropolis–Hastings acceptance, trace mutation + validation,
//! learned-cost-model candidate filtering, and a task scheduler for
//! end-to-end models.
//!
//! The search consumes a [`crate::ctx::TuneContext`] — space generation,
//! mutation, and candidate postprocessing all go through its pluggable
//! component families. No concrete schedule rule is named anywhere in
//! this layer; that compile-time inversion is what makes custom rules
//! first-class (see `rust/tests/space_registry.rs`).

pub mod allocation;
pub mod evolutionary;
pub mod parallel;
pub mod task_scheduler;

// Re-exported for benches/property tests that mutate traces standalone.
pub use crate::ctx::mutate;
pub use allocation::{
    Allocation, AllocationPolicy, AllocationReport, GradientGain, Greedy, RoundRobin, TaskLedger,
    TaskShare,
};
pub use evolutionary::{EvolutionarySearch, QualityPoint, ReplaySearch, SearchConfig, TuneResult};
pub use parallel::{BoundedQueue, MeasureRecord, SharedMeasurer};
pub use task_scheduler::{Task, TaskScheduler};

use crate::sim::{simulate, Target};
use crate::tir::Program;

/// The hardware measurement oracle `f(e)` (paper Figure 7's "hardware"
/// box). Returns `None` for programs that are invalid on the target
/// (scratchpad overflow, thread limits, unsupported intrinsics).
/// `Send` so the search pipeline can hand the oracle to its measurement
/// worker; exclusive access is still serialized (see
/// [`parallel::SharedMeasurer`]) — implementations need no internal
/// locking of their own.
pub trait Measurer: Send {
    fn measure(&mut self, prog: &Program) -> Option<f64>;
    /// Number of measurements performed so far.
    fn count(&self) -> usize;
    /// Name of the target this oracle measures on — stamped into tuning
    /// records and part of the workload identity, so a database never
    /// silently mixes targets. Required (no default): a measurer that
    /// forgot to name its target would silently pool every device's
    /// records into one workload. Returns an owned `String` because
    /// device-discovered names (e.g. the PJRT platform string folded
    /// into [`crate::runtime::PjrtGmmMeasurer`]'s name) are not
    /// compile-time constants, and a borrowed return could not cross the
    /// mutex of [`parallel::SharedMeasurer`].
    fn target_name(&self) -> String;
}

/// Measurer backed by the analytical hardware simulator (the default
/// testbed substitute — DESIGN.md §3).
pub struct SimMeasurer {
    pub target: Target,
    n: usize,
}

impl SimMeasurer {
    pub fn new(target: Target) -> SimMeasurer {
        SimMeasurer { target, n: 0 }
    }
}

impl Measurer for SimMeasurer {
    fn measure(&mut self, prog: &Program) -> Option<f64> {
        self.n += 1;
        simulate(prog, &self.target).ok().map(|r| r.total_s)
    }

    fn count(&self) -> usize {
        self.n
    }

    fn target_name(&self) -> String {
        self.target.name.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn sim_measurer_counts_and_rejects() {
        let mut m = SimMeasurer::new(Target::gpu());
        let ok = workloads::matmul(1, 32, 32, 32);
        assert!(m.measure(&ok).is_some());
        // 4096 threads on one loop -> invalid on GPU.
        let mut s = crate::schedule::Schedule::new(workloads::matmul(1, 4096, 16, 16), 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        s.bind(loops[1], "threadIdx.x").unwrap();
        assert!(m.measure(&s.prog).is_none());
        assert_eq!(m.count(), 2);
    }
}
