//! Parallel execution primitives for the search pipeline: a bounded
//! multi-consumer work queue, a thread-safe measurer wrapper, and an
//! order-preserving parallel map over owned items.
//!
//! Everything here is built on `std::sync` + scoped threads only (the
//! offline image vendors no `rayon`/`crossbeam`), and everything preserves
//! the search's determinism contract: results are always merged in
//! submission/item order, so the number of OS threads never changes what
//! the algorithm computes — only how fast.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use crate::search::Measurer;
use crate::tir::Program;

/// A bounded FIFO work queue: producers block when the queue is full,
/// consumers block when it is empty, and `close()` drains everyone out.
/// This is the backpressure channel between candidate selection and the
/// measurement workers (top-k measurement overlaps the next generation's
/// mutation without unbounded buffering).
pub struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        BoundedQueue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Enqueue, blocking while the queue is at capacity. Returns `false`
    /// if the queue was closed (the item is dropped).
    pub fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        while !st.closed && st.items.len() >= self.capacity {
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return false;
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        true
    }

    /// Dequeue, blocking while empty; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(x) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(x);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking dequeue: `Some` if an item is ready right now, `None`
    /// when the queue is momentarily empty (closed or not). This is how a
    /// batching consumer — e.g. the sharded database's group-commit
    /// writer ([`crate::db::sharded::group_commit_writer`]) — opportunistically
    /// extends a batch: one blocking [`Self::pop`] for the first item,
    /// then `try_pop` until the queue runs dry, then one flush for the
    /// whole batch.
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Close the queue: blocked producers give up, consumers drain what
    /// remains and then see `None`.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// One entry of the measurement ledger: what was measured, in which
/// submission slot, and what the hardware said.
#[derive(Debug, Clone)]
pub struct MeasureRecord {
    /// Submission index within the round (drain order key).
    pub slot: usize,
    /// Latency in seconds; `None` = rejected by the hardware validator.
    pub latency_s: Option<f64>,
}

/// Thread-safe wrapper around a `Measurer`: serializes access behind a
/// mutex and keeps a slot-tagged ledger of every measurement taken
/// through the pipeline entry point ([`Self::measure`]) — the ledger is
/// how the measurement worker hands results back in submission order.
/// The wrapped oracle stays free to be single-threaded (PJRT clients,
/// shared simulators); concurrency happens in the pipeline around it.
pub struct SharedMeasurer<'a> {
    inner: Mutex<&'a mut dyn Measurer>,
    ledger: Mutex<Vec<MeasureRecord>>,
}

impl<'a> SharedMeasurer<'a> {
    pub fn new(inner: &'a mut dyn Measurer) -> SharedMeasurer<'a> {
        SharedMeasurer {
            inner: Mutex::new(inner),
            ledger: Mutex::new(Vec::new()),
        }
    }

    /// Measure under the lock and record the outcome in the ledger.
    pub fn measure(&self, slot: usize, prog: &Program) -> Option<f64> {
        let latency_s = self.inner.lock().unwrap().measure(prog);
        self.ledger.lock().unwrap().push(MeasureRecord { slot, latency_s });
        latency_s
    }

    pub fn count(&self) -> usize {
        self.inner.lock().unwrap().count()
    }

    /// Take the accumulated ledger (clears it).
    pub fn take_ledger(&self) -> Vec<MeasureRecord> {
        std::mem::take(&mut self.ledger.lock().unwrap())
    }
}

/// Adapter so a `&SharedMeasurer` can be handed to APIs that expect an
/// exclusive `&mut dyn Measurer` (each thread makes its own reference).
/// Goes straight to the wrapped oracle without touching the ledger — the
/// ledger is the measurement pipeline's slot-ordered result channel, and
/// adapter calls (e.g. task-scheduler searches sharing one oracle) have
/// no slot to record.
impl Measurer for &SharedMeasurer<'_> {
    fn measure(&mut self, prog: &Program) -> Option<f64> {
        self.inner.lock().unwrap().measure(prog)
    }

    fn count(&self) -> usize {
        SharedMeasurer::count(*self)
    }

    fn target_name(&self) -> String {
        self.inner.lock().unwrap().target_name()
    }
}

/// Map `f` over owned `items` on up to `threads` OS threads, returning
/// results in item order. With `threads <= 1` (or one item) this runs
/// inline with no thread spawned — the serial reference path that the
/// determinism tests compare against.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("item taken once");
                let r = f(i, item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..37).collect();
        let serial = parallel_map(items.clone(), 1, |i, x| i * 1000 + x * x);
        let parallel = parallel_map(items, 4, |i, x| i * 1000 + x * x);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn parallel_map_runs_every_item_once() {
        let calls = AtomicUsize::new(0);
        let out = parallel_map((0..64).collect::<Vec<i32>>(), 8, |_, x| {
            calls.fetch_add(1, Ordering::Relaxed);
            x + 1
        });
        assert_eq!(calls.load(Ordering::Relaxed), 64);
        assert_eq!(out, (1..=64).collect::<Vec<i32>>());
    }

    #[test]
    fn bounded_queue_fifo_and_close() {
        let q = BoundedQueue::new(4);
        for i in 0..3 {
            assert!(q.push(i));
        }
        assert_eq!(q.pop(), Some(0));
        q.close();
        assert!(!q.push(99));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bounded_queue_try_pop_never_blocks() {
        let q = BoundedQueue::new(4);
        assert_eq!(q.try_pop(), None, "empty queue answers None immediately");
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.try_pop(), Some(1), "FIFO order");
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), None);
        q.close();
        assert_eq!(q.try_pop(), None, "closed + drained is None, not a hang");
    }

    #[test]
    fn bounded_queue_backpressure_across_threads() {
        let q = BoundedQueue::new(2);
        let total = 100usize;
        let consumed = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            s.spawn(|| {
                while let Some(x) = q.pop() {
                    consumed.lock().unwrap().push(x);
                }
            });
            s.spawn(|| {
                while let Some(x) = q.pop() {
                    consumed.lock().unwrap().push(x);
                }
            });
            for i in 0..total {
                assert!(q.push(i));
            }
            q.close();
        });
        let mut got = consumed.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..total).collect::<Vec<usize>>());
    }

    #[test]
    fn shared_measurer_ledger_records_slots() {
        use crate::search::SimMeasurer;
        use crate::sim::Target;
        let mut inner = SimMeasurer::new(Target::cpu_avx512());
        let shared = SharedMeasurer::new(&mut inner);
        let prog = crate::workloads::matmul(1, 16, 16, 16);
        assert!(shared.measure(7, &prog).is_some());
        assert!(shared.measure(3, &prog).is_some());
        // The `&mut dyn Measurer` adapter reaches the oracle but not the
        // ledger (no slot to record).
        let mut adapter: &SharedMeasurer = &shared;
        assert!(Measurer::measure(&mut adapter, &prog).is_some());
        let ledger = shared.take_ledger();
        assert_eq!(ledger.len(), 2);
        assert_eq!(ledger[0].slot, 7);
        assert_eq!(ledger[1].slot, 3);
        assert!(shared.take_ledger().is_empty());
        assert_eq!(shared.count(), 3);
    }
}
