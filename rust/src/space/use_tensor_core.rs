//! Use-Tensor-Core: the paper's hardware-specific module (§6.3, Appendix
//! A.3/A.4). Maps matmul-like blocks onto a tensor intrinsic: tiles the
//! (i, j, k) loops so a `(m, n, k)` fragment sits innermost, binds the
//! outer tiles to the GPU grid, and `tensorize`s the fragment.
//!
//! Hardware adaptation (DESIGN.md §Hardware-Adaptation): on the CUDA
//! target this is WMMA 16x16x16; the same module parameterized with
//! `mxu_128x128` expresses the TPU MXU systolic mapping, which is what the
//! Pallas L1 kernel realizes with `BlockSpec` tiles on the Python side.

use crate::schedule::{LoopRv, SchResult, Schedule};
use crate::schedule::blockize::find_intrin;
use crate::sim::Target;
use crate::space::{analysis::is_matmul_like, attempt, RuleOutcome, ScheduleRule};
use crate::tir::analysis::{classify_loop, LoopClass};
use crate::tir::LoopKind;
use crate::trace::FactorArg;

pub struct UseTensorCore {
    pub intrin: &'static str,
}

impl UseTensorCore {
    /// CUDA WMMA 16x16x16 fragments (the paper's RTX 3070 experiments).
    pub fn wmma() -> UseTensorCore {
        UseTensorCore { intrin: "wmma_16x16x16" }
    }

    /// TPU MXU 128x128 systolic tiles (hardware-adaptation variant).
    pub fn mxu() -> UseTensorCore {
        UseTensorCore { intrin: "mxu_128x128" }
    }

    fn transform(&self, s: &mut Schedule, block_name: &str) -> SchResult<()> {
        let (fm, fn_, fk) = find_intrin(self.intrin)
            .ok_or_else(|| crate::schedule::ScheduleError::TensorizeMismatch(
                format!("unknown intrinsic {}", self.intrin),
            ))?
            .dims;
        let b = s.get_block(block_name)?;
        let loops = s.get_loops(b)?;
        let mut spatial: Vec<LoopRv> = Vec::new();
        let mut reduce: Vec<LoopRv> = Vec::new();
        for &l in &loops {
            let item = s.loop_item(l)?;
            if s.prog.loop_data(item).kind != LoopKind::Serial {
                return Err(crate::schedule::ScheduleError::WrongLoopKind(
                    "tensor-core tiling requires serial loops".into(),
                ));
            }
            match classify_loop(&s.prog, item) {
                LoopClass::Spatial => spatial.push(l),
                LoopClass::Reduce => reduce.push(l),
                LoopClass::Unused => {}
                LoopClass::Mixed => {
                    return Err(crate::schedule::ScheduleError::Unsupported("mixed loop".into()))
                }
            }
        }
        if spatial.len() < 2 || reduce.is_empty() {
            return Err(crate::schedule::ScheduleError::TensorizeMismatch(
                "need two spatial and one reduction loop".into(),
            ));
        }
        // Fragment loops: the two innermost spatial dims (i, j) + the last
        // reduction dim (k). Batch/head loops stay outside.
        let li = spatial[spatial.len() - 2];
        let lj = spatial[spatial.len() - 1];
        let lk = *reduce.last().unwrap();
        let (ei, ej, ek) = (
            s.prog.loop_data(s.loop_item(li)?).extent,
            s.prog.loop_data(s.loop_item(lj)?).extent,
            s.prog.loop_data(s.loop_item(lk)?).extent,
        );
        if ei % fm != 0 || ej % fn_ != 0 || ek % fk != 0 {
            return Err(crate::schedule::ScheduleError::TensorizeMismatch(format!(
                "extents ({ei},{ej},{ek}) not divisible by fragment ({fm},{fn_},{fk})"
            )));
        }
        // Peel the fragment: l -> [l_outer, fragment]; then sample-tile the
        // outer part two ways for the grid/thread levels.
        let peel = |s: &mut Schedule, l: LoopRv, frag: i64| -> SchResult<(LoopRv, LoopRv, LoopRv)> {
            let e = s.prog.loop_data(s.loop_item(l)?).extent;
            let parts = s.split(l, &[FactorArg::Lit(e / frag), FactorArg::Lit(frag)])?;
            let t = s.sample_perfect_tile(parts[0], 2, 0)?;
            let outer = s.split(parts[0], &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])?;
            Ok((outer[0], outer[1], parts[1]))
        };
        let (i0, i1, i_f) = peel(s, li, fm)?;
        let (j0, j1, j_f) = peel(s, lj, fn_)?;
        let (k0, k1, k_f) = peel(s, lk, fk)?;
        // i0 j0 | i1 j1 | k0 k1 | fragment(i_f j_f k_f)
        s.reorder(&[i0, j0, i1, j1, k0, k1, i_f, j_f, k_f])?;
        let grid = s.fuse(&[i0, j0])?;
        s.bind(grid, "blockIdx.x")?;
        let warp = s.fuse(&[i1, j1])?;
        s.bind(warp, "threadIdx.y")?;
        // The fragment subtree must be exactly (fm, fn, fk) — tensorize
        // re-validates and swaps in the opaque intrinsic block.
        s.tensorize(i_f, self.intrin)?;
        Ok(())
    }
}

impl ScheduleRule for UseTensorCore {
    fn name(&self) -> &str {
        "use-tensor-core"
    }

    fn describe(&self) -> String {
        "map matmul-like blocks onto the tensor intrinsic, forking tensorized + plain".into()
    }

    fn params(&self) -> Vec<(String, String)> {
        vec![("intrin".into(), self.intrin.to_string())]
    }

    fn apply(&self, sch: Schedule, block_name: &str, target: &Target) -> RuleOutcome {
        let supported = target.tensor_intrins.iter().any(|i| *i == self.intrin);
        let applicable = supported
            && sch
                .prog
                .find_block(block_name)
                .map(|b| is_matmul_like(&sch.prog, b))
                .unwrap_or(false);
        if !applicable {
            return RuleOutcome::Skip(sch);
        }
        // Fork the space: tensorized + generic (the paper composes
        // Use-Tensor-Core *with* the generic modules; non-tensorizable
        // decisions fall back to multi-level tiling). A shape mismatch
        // (extents not divisible by the fragment) is an expected fallback,
        // but still surfaced as Fail so --explain-space can say *why* a
        // space never tensorized.
        match attempt(&sch, |s| self.transform(s, block_name)) {
            Ok(out) => RuleOutcome::Applied(vec![out, sch]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, Target};
    use crate::tir::analysis::program_flops;
    use crate::workloads;

    #[test]
    fn tensorizes_gmm() {
        let t = Target::gpu();
        let m = UseTensorCore::wmma();
        let prog = workloads::matmul(1, 128, 128, 128);
        let flops = program_flops(&prog);
        let variants = m.apply(Schedule::new(prog, 6), "matmul", &t).into_variants();
        assert_eq!(variants.len(), 2);
        let tc = &variants[0];
        tc.prog.check_integrity().unwrap();
        assert_eq!(program_flops(&tc.prog), flops);
        let opaque = tc.prog.find_block("matmul_o").unwrap();
        assert_eq!(
            tc.prog.block_data(opaque).annotations["tensor_intrin"],
            "wmma_16x16x16"
        );
    }

    #[test]
    fn tensorized_beats_plain_binding_on_sim() {
        let t = Target::gpu();
        let m = UseTensorCore::wmma();
        let tb = crate::space::ThreadBind::new();
        let best_tc = (0..8)
            .filter_map(|seed| {
                let prog = workloads::matmul(1, 512, 512, 512);
                let v = m.apply(Schedule::new(prog, seed), "matmul", &t).into_variants();
                simulate(&v[0].prog, &t).ok().map(|r| r.total_s)
            })
            .fold(f64::INFINITY, f64::min);
        let best_plain = (0..8)
            .filter_map(|seed| {
                let prog = workloads::matmul(1, 512, 512, 512);
                let v = tb.apply(Schedule::new(prog, seed), "matmul", &t).into_variants();
                simulate(&v[0].prog, &t).ok().map(|r| r.total_s)
            })
            .fold(f64::INFINITY, f64::min);
        assert!(
            best_tc < best_plain,
            "tensor core {best_tc} vs plain {best_plain}"
        );
    }

    #[test]
    fn odd_shapes_fall_back() {
        let t = Target::gpu();
        let m = UseTensorCore::wmma();
        // 100 is not divisible by 16.
        let prog = workloads::matmul(1, 100, 100, 100);
        let variants = m.apply(Schedule::new(prog, 6), "matmul", &t).into_variants();
        assert_eq!(variants.len(), 1);
        assert!(variants[0].trace.is_empty());
    }

    #[test]
    fn cpu_target_not_applicable() {
        let t = Target::cpu_avx512();
        let m = UseTensorCore::wmma();
        let prog = workloads::matmul(1, 128, 128, 128);
        let variants = m.apply(Schedule::new(prog, 6), "matmul", &t).into_variants();
        assert_eq!(variants.len(), 1);
        assert!(variants[0].trace.is_empty());
    }

    #[test]
    fn mxu_variant_applies_at_128() {
        let mut t = Target::tpu_like();
        t.kind = crate::sim::TargetKind::Gpu;
        let m = UseTensorCore::mxu();
        let prog = workloads::matmul(1, 512, 512, 512);
        let variants = m.apply(Schedule::new(prog, 6), "matmul", &t).into_variants();
        assert_eq!(variants.len(), 2);
        let opaque = variants[0].prog.find_block("matmul_o").unwrap();
        assert_eq!(
            variants[0].prog.block_data(opaque).annotations["tensor_intrin"],
            "mxu_128x128"
        );
    }
}
