//! Parallel-Vectorize-Unroll: CPU post-tiling module. Fuses and
//! parallelizes the outer data-parallel loops across cores, vectorizes the
//! innermost spatial loop with SIMD, and samples an auto-unroll pragma —
//! the CPU half of the paper's Figure 2 example pipeline.

use crate::schedule::{LoopRv, SchResult, Schedule};
use crate::sim::Target;
use crate::space::{attempt, RuleOutcome, ScheduleRule};
use crate::tir::analysis::{classify_loop, LoopClass};
use crate::tir::LoopKind;

pub struct ParallelVectorizeUnroll {
    /// Stop fusing outer loops once the fused extent reaches
    /// `cores * max_jobs_per_core`.
    pub max_jobs_per_core: i64,
    /// Auto-unroll pragma candidates for `sample_categorical`.
    pub unroll_steps: Vec<i64>,
}

impl ParallelVectorizeUnroll {
    pub fn new() -> ParallelVectorizeUnroll {
        ParallelVectorizeUnroll {
            max_jobs_per_core: 16,
            unroll_steps: vec![0, 16, 64, 512],
        }
    }

    fn transform(&self, s: &mut Schedule, block_name: &str, target: &Target) -> SchResult<()> {
        let b = s.get_block(block_name)?;
        let loops = s.get_loops(b)?;
        if loops.is_empty() {
            return Ok(());
        }
        // Skip blocks already under a parallel/bound loop (e.g. fused into
        // an already-parallelized producer nest).
        for &l in &loops {
            let item = s.loop_item(l)?;
            if !matches!(
                s.prog.loop_data(item).kind,
                LoopKind::Serial | LoopKind::Vectorized | LoopKind::Unrolled
            ) {
                return Ok(());
            }
        }

        // ---- parallelize: maximal leading run of spatial serial loops ----
        let max_parallel = target.num_cores as i64 * self.max_jobs_per_core;
        let mut run: Vec<LoopRv> = Vec::new();
        let mut extent = 1i64;
        for &l in &loops {
            let item = s.loop_item(l)?;
            let ld = s.prog.loop_data(item);
            let class = classify_loop(&s.prog, item);
            if ld.kind != LoopKind::Serial
                || !(class == LoopClass::Spatial || class == LoopClass::Unused)
            {
                break;
            }
            run.push(l);
            extent *= ld.extent;
            if extent >= max_parallel {
                break;
            }
        }
        if !run.is_empty() && extent > 1 {
            // Never swallow the whole nest: keep at least one loop below for
            // vectorization when the run covers every loop of an
            // elementwise block.
            if run.len() == loops.len() && run.len() > 1 {
                run.pop();
            }
            let fused = if run.len() > 1 { s.fuse(&run)? } else { run[0] };
            s.parallel(fused)?;
        }

        // ---- vectorize: fuse the trailing run of spatial serial loops,
        // then vectorize the fused loop. Fusing first matters: a lone
        // innermost extent of e.g. 7 fills 7/16 SIMD lanes, but the fused
        // tile (cog3*oh3*ow3) fills them almost completely. Unit loops
        // (kh=kw=1 of a 1x1 conv) are compiled away and skipped.
        let loops_now = s.get_loops(b)?;
        let mut tail: Vec<LoopRv> = Vec::new();
        for &inner in loops_now.iter().rev() {
            let item = s.loop_item(inner)?;
            let ld = s.prog.loop_data(item);
            if ld.extent <= 1 && tail.is_empty() {
                continue; // trailing unit loops
            }
            if ld.kind == LoopKind::Serial
                && classify_loop(&s.prog, item) == LoopClass::Spatial
                && ld.extent >= 1
            {
                tail.push(inner);
            } else {
                break;
            }
        }
        tail.reverse(); // outermost-first for fuse
        if !tail.is_empty() {
            // fuse() requires a clean single-child chain; unit loops in
            // between break it, so fall back to vectorizing just the
            // innermost non-unit loop when fusion is not possible.
            let fused = if tail.len() > 1 {
                match s.fuse(&tail) {
                    Ok(f) => Some(f),
                    Err(_) => tail.iter().rev().find(|&&l| {
                        s.loop_item(l)
                            .map(|i| s.prog.loop_data(i).extent > 1)
                            .unwrap_or(false)
                    }).copied(),
                }
            } else {
                Some(tail[0])
            };
            if let Some(f) = fused {
                let fi = s.loop_item(f)?;
                if s.prog.loop_data(fi).extent > 1 {
                    s.vectorize(f)?;
                }
            }
        }

        // ---- auto-unroll pragma on the outermost loop ----
        let probs = vec![1.0 / self.unroll_steps.len() as f64; self.unroll_steps.len()];
        let step = s.sample_categorical(&self.unroll_steps, &probs)?;
        let v = s.expr_value(step).to_string();
        let outer = s.get_loops(b)?[0];
        s.annotate_loop(outer, "pragma_auto_unroll_max_step", &v)?;
        Ok(())
    }
}

impl Default for ParallelVectorizeUnroll {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleRule for ParallelVectorizeUnroll {
    fn name(&self) -> &str {
        "parallel-vectorize-unroll"
    }

    fn describe(&self) -> String {
        "parallelize outer spatial loops, vectorize the inner tile, sample auto-unroll".into()
    }

    fn params(&self) -> Vec<(String, String)> {
        let steps: Vec<String> = self.unroll_steps.iter().map(|v| v.to_string()).collect();
        vec![
            ("max-jobs-per-core".into(), self.max_jobs_per_core.to_string()),
            ("unroll-steps".into(), steps.join("/")),
        ]
    }

    fn apply(&self, sch: Schedule, block_name: &str, target: &Target) -> RuleOutcome {
        // No separate applicability gate: the transform itself no-ops on
        // already-parallel nests (still `Applied` — it records state
        // queries into the trace), so an Err here is always structural.
        match attempt(&sch, |s| self.transform(s, block_name, target)) {
            Ok(out) => RuleOutcome::Applied(vec![out]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, Target};
    use crate::workloads;

    fn kinds(s: &Schedule) -> Vec<LoopKind> {
        s.prog
            .preorder()
            .into_iter()
            .filter(|&i| s.prog.is_loop(i))
            .map(|i| s.prog.loop_data(i).kind.clone())
            .collect()
    }

    #[test]
    fn parallelizes_and_vectorizes_matmul() {
        let t = Target::cpu_avx512();
        let prog = workloads::matmul(1, 256, 256, 256);
        let m = ParallelVectorizeUnroll::new();
        let out = m.apply(Schedule::new(prog.clone(), 1), "matmul", &t).into_variants().pop().unwrap();
        let ks = kinds(&out);
        assert!(ks.contains(&LoopKind::Parallel));
        // Innermost loop of matmul is k (reduction) -> NOT vectorized.
        assert!(!ks.contains(&LoopKind::Vectorized));
        let base = simulate(&prog, &t).unwrap().total_s;
        let opt = simulate(&out.prog, &t).unwrap().total_s;
        assert!(opt < base, "{opt} vs {base}");
    }

    #[test]
    fn vectorizes_innermost_spatial_of_relu() {
        let t = Target::cpu_avx512();
        let prog = workloads::add2d(512, 512);
        let m = ParallelVectorizeUnroll::new();
        let out = m.apply(Schedule::new(prog, 1), "add", &t).into_variants().pop().unwrap();
        let ks = kinds(&out);
        assert!(ks.contains(&LoopKind::Parallel));
        assert!(ks.contains(&LoopKind::Vectorized));
    }

    #[test]
    fn unroll_annotation_recorded() {
        let t = Target::cpu_avx512();
        let prog = workloads::matmul(1, 64, 64, 64);
        let m = ParallelVectorizeUnroll::new();
        let out = m.apply(Schedule::new(prog, 9), "matmul", &t).into_variants().pop().unwrap();
        let has_pragma = out
            .prog
            .preorder()
            .into_iter()
            .filter(|&i| out.prog.is_loop(i))
            .any(|i| {
                out.prog
                    .loop_data(i)
                    .annotations
                    .contains_key("pragma_auto_unroll_max_step")
            });
        assert!(has_pragma);
        assert_eq!(out.trace.sampling_indices().len(), 1);
    }

    #[test]
    fn skips_already_parallel_nests() {
        let t = Target::cpu_avx512();
        let prog = workloads::matmul(1, 64, 64, 64);
        let mut s = Schedule::new(prog, 0);
        let b = s.get_block("matmul").unwrap();
        let loops = s.get_loops(b).unwrap();
        s.parallel(loops[1]).unwrap();
        let n_insts = s.trace.len();
        let m = ParallelVectorizeUnroll::new();
        let out = m.apply(s, "matmul", &t).into_variants().pop().unwrap();
        // Only the (re-recorded) state queries were added, no transforms.
        assert!(out
            .trace
            .insts
            .iter()
            .skip(n_insts)
            .all(|i| !matches!(i.opcode(), "parallel" | "vectorize")));
    }
}
