//! Auto-Inline: fold elementwise blocks into their consumers (or, for
//! output blocks, back into their producer) for memory-bandwidth
//! efficiency — the paper's canonical example of a generic module (§3.2).

use crate::schedule::Schedule;
use crate::sim::Target;
use crate::space::{attempt, RuleOutcome, ScheduleRule};

/// Deterministic module: no sampling. When the block is a trivially-written
/// assignment it is inlined forward into its consumers; when it is the
/// final output of a chain it is reverse-inlined into its producer.
pub struct AutoInline {
    /// Also attempt reverse inlining of output blocks (default true).
    pub into_producer: bool,
}

impl AutoInline {
    pub fn new() -> AutoInline {
        AutoInline { into_producer: true }
    }
}

impl Default for AutoInline {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleRule for AutoInline {
    fn name(&self) -> &str {
        "auto-inline"
    }

    fn describe(&self) -> String {
        "fold trivially-written elementwise blocks into their consumers (or producer)".into()
    }

    fn params(&self) -> Vec<(String, String)> {
        vec![("into-producer".into(), self.into_producer.to_string())]
    }

    fn apply(&self, sch: Schedule, block_name: &str, _target: &Target) -> RuleOutcome {
        // This is a probe rule: inline errors *are* the applicability
        // analysis ("attempt the transformation"), so a block that cannot
        // be inlined either way is a Skip, never a structural failure.
        // Forward inline into consumers.
        if let Ok(s) = attempt(&sch, |s| {
            let b = s.get_block(block_name)?;
            s.compute_inline(b)
        }) {
            return RuleOutcome::Applied(vec![s]);
        }
        // Reverse inline into the single producer (output elementwise blocks).
        if self.into_producer {
            if let Ok(s) = attempt(&sch, |s| {
                let b = s.get_block(block_name)?;
                s.reverse_compute_inline(b)
            }) {
                return RuleOutcome::Applied(vec![s]);
            }
        }
        RuleOutcome::Skip(sch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn apply_all(mut sch: Schedule, m: &AutoInline) -> Schedule {
        let names: Vec<String> = sch
            .prog
            .blocks()
            .iter()
            .map(|&b| sch.prog.block_data(b).name.clone())
            .collect();
        let t = crate::sim::Target::cpu_avx512();
        for n in names {
            if sch.prog.find_block(&n).is_some() {
                sch = m.apply(sch, &n, &t).into_variants().pop().unwrap();
            }
        }
        sch
    }

    #[test]
    fn inlines_bias_into_relu_in_fused_dense() {
        let prog = workloads::fused_dense(32, 64, 32);
        let sch = Schedule::new(prog, 0);
        let out = apply_all(sch, &AutoInline::new());
        // bias_add folds into relu; dense (reduction) and relu remain.
        assert!(out.prog.find_block("bias_add").is_none());
        assert!(out.prog.find_block("dense").is_some());
        assert!(out.prog.find_block("relu").is_some());
    }

    #[test]
    fn inlines_transpose_into_batch_matmul() {
        let prog = workloads::transpose_batch_matmul(32, 4, 16);
        let sch = Schedule::new(prog, 0);
        let out = apply_all(sch, &AutoInline::new());
        assert!(out.prog.find_block("transpose").is_none());
        assert!(out.prog.find_block("batch_matmul").is_some());
    }

    #[test]
    fn softmax_exp_inlines_into_both_consumers() {
        let prog = workloads::softmax(1, 64, 64);
        let sch = Schedule::new(prog, 0);
        let out = apply_all(sch, &AutoInline::new());
        assert!(out.prog.find_block("exp").is_none());
        // Reductions cannot be inlined.
        assert!(out.prog.find_block("row_max").is_some());
        assert!(out.prog.find_block("row_sum").is_some());
    }

    #[test]
    fn reduction_block_untouched() {
        let prog = workloads::matmul(1, 32, 32, 32);
        let sch = Schedule::new(prog, 0);
        let out = apply_all(sch, &AutoInline::new());
        assert!(out.prog.find_block("matmul").is_some());
    }
}
