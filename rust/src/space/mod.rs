//! Composable search-space construction: schedule rules (§3.2).
//!
//! A [`ScheduleRule`] is the paper's *transformation module* (renamed to
//! match its role in the [`crate::ctx::TuneContext`] rule registry): a
//! named, reusable unit of (program analysis + sampling + stochastic
//! transformation). The [`SpaceGenerator`] composes a list of rules over
//! every block of the program (Figure 5's algorithm): visiting blocks in
//! execution order and applying each rule in turn, flat-mapping over the
//! design variants each application returns. The resulting schedules carry
//! *design-space traces* — linearized probabilistic programs whose sampling
//! decisions the search later re-draws and mutates.
//!
//! Rules report a tri-state [`RuleOutcome`] rather than a bare variant
//! list, so a rule that *fails for a structural reason* is distinguishable
//! from one that simply does not apply — the generator tallies both per
//! rule into [`diag::RuleDiag`] counters surfaced by `tune
//! --explain-space`. Which rules compose a space is no longer hardcoded
//! here: per-target defaults live as data in [`crate::ctx::registry`], and
//! custom rules register through the same [`crate::ctx::RegistrySet`] API.

pub mod add_rfactor;
pub mod auto_inline;
pub mod cross_thread_reduction;
pub mod diag;
pub mod layout_rewrite;
pub mod multi_level_tiling;
pub mod parallel_vectorize_unroll;
pub mod random_compute_location;
pub mod thread_bind;
pub mod use_tensor_core;

pub use add_rfactor::AddRfactor;
pub use auto_inline::AutoInline;
pub use cross_thread_reduction::CrossThreadReduction;
pub use diag::RuleDiag;
pub use layout_rewrite::LayoutRewrite;
pub use multi_level_tiling::MultiLevelTiling;
pub use parallel_vectorize_unroll::ParallelVectorizeUnroll;
pub use random_compute_location::RandomComputeLocation;
pub use thread_bind::ThreadBind;
pub use use_tensor_core::UseTensorCore;

use std::sync::Arc;

use crate::schedule::{SchResult, Schedule, ScheduleError};
use crate::sim::Target;
use crate::telemetry::{self, Counter, Metrics};
use crate::tir::Program;

/// What one rule application did to one (schedule, block) pair.
///
/// The schedule always travels through: `Skip`/`Fail` return the input
/// unchanged so the design space is identical to the pre-tri-state
/// behaviour ("not applicable" used to be a silent pass-through), while
/// the generator counts each arm separately for `--explain-space`.
pub enum RuleOutcome {
    /// The rule transformed the schedule into one or more design
    /// variants (more than one forks the space, e.g. tensorized + plain).
    Applied(Vec<Schedule>),
    /// The rule's own applicability analysis said "not my block";
    /// the input passes through untouched.
    Skip(Schedule),
    /// The rule considered the block applicable but the transformation
    /// errored — a *structural* failure, previously indistinguishable
    /// from `Skip` because `try_transform` swallowed the error. The
    /// input passes through; the error is surfaced in the diagnostics.
    Fail(Schedule, ScheduleError),
}

impl RuleOutcome {
    /// Collapse to the variant list the generator flat-maps over (and
    /// the shape the old `TransformModule::apply` returned): `Applied`
    /// yields its variants, `Skip`/`Fail` yield the untouched input.
    pub fn into_variants(self) -> Vec<Schedule> {
        match self {
            RuleOutcome::Applied(v) => v,
            RuleOutcome::Skip(s) | RuleOutcome::Fail(s, _) => vec![s],
        }
    }
}

/// A composable schedule rule (paper §3.2, Figure 4; the former
/// `TransformModule`, renamed and extended with a describe/params
/// surface for the rule registry and `--explain-space`).
///
/// `apply` receives one schedule state and the *name* of the block to
/// consider (names are stable across design variants; RV handles are not)
/// and reports a [`RuleOutcome`]. `Send + Sync` because a
/// [`crate::ctx::TuneContext`] is shared across the search's worker
/// threads; rules are immutable configuration, never mutable state.
pub trait ScheduleRule: Send + Sync {
    /// Registry name (kebab-case, unique within a rule set).
    fn name(&self) -> &str;

    /// One-line human description for `--explain-space` and docs.
    fn describe(&self) -> String {
        String::new()
    }

    /// Named parameters `(key, value)` for provenance and diagnostics.
    fn params(&self) -> Vec<(String, String)> {
        Vec::new()
    }

    fn apply(&self, sch: Schedule, block_name: &str, target: &Target) -> RuleOutcome;
}

/// Run `f` on a clone of `sch`; return the transformed schedule if every
/// primitive succeeded, or the first error otherwise. This is the
/// standard rule idiom — probe applicability by attempting the
/// transformation — with the error *surfaced* instead of swallowed, so
/// rules can report `RuleOutcome::Fail` for structural failures.
pub fn attempt(sch: &Schedule, f: impl FnOnce(&mut Schedule) -> SchResult<()>) -> SchResult<Schedule> {
    let mut c = sch.clone();
    f(&mut c).map(|()| c)
}

/// Composes schedule rules into a search-space generator (Figure 5 left:
/// `Compose([m1, ..., mk])`). Construct directly from rule instances, or
/// — the normal path — through [`crate::ctx::TuneContext`], which
/// resolves named rule sets against the registry.
pub struct SpaceGenerator {
    rules: Vec<Box<dyn ScheduleRule>>,
    pub target: Target,
    /// Per-rule applicability/error counters, parallel to `rules`,
    /// registered in `metrics`.
    diag: Vec<RuleDiag>,
    /// Per-generator metrics registry: the rule diag counters plus the
    /// generation totals below. [`crate::ctx::TuneContext`] adopts this
    /// registry, so context-level instruments (postprocs, mutators) land
    /// here too. Per-generator rather than process-global because
    /// `--explain-space` reports *this* context's exact counts.
    metrics: Arc<Metrics>,
    gen_calls: Arc<Counter>,
    gen_states: Arc<Counter>,
    /// The same two totals mirrored into the process-global registry, so
    /// a serving front's `/metrics` shows space-generation work done by
    /// tune-on-miss without reaching into per-context state.
    global_gen_calls: Arc<Counter>,
    global_gen_states: Arc<Counter>,
}

impl SpaceGenerator {
    pub fn new(rules: Vec<Box<dyn ScheduleRule>>, target: Target) -> SpaceGenerator {
        let metrics = Arc::new(Metrics::new());
        let diag = rules.iter().map(|r| RuleDiag::new(r.name(), &metrics)).collect();
        const CALLS: (&str, &str) = ("space_generations_total", "design-space generate() calls");
        const STATES: (&str, &str) = ("space_states_total", "schedules produced by generate()");
        let gen_calls = metrics.counter(CALLS.0, CALLS.1);
        let gen_states = metrics.counter(STATES.0, STATES.1);
        let global = telemetry::global();
        let global_gen_calls = global.counter(CALLS.0, CALLS.1);
        let global_gen_states = global.counter(STATES.0, STATES.1);
        SpaceGenerator {
            rules,
            target,
            diag,
            metrics,
            gen_calls,
            gen_states,
            global_gen_calls,
            global_gen_states,
        }
    }

    /// The registry holding this generator's diagnostics counters.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The composed rules, in application order.
    pub fn rules(&self) -> &[Box<dyn ScheduleRule>] {
        &self.rules
    }

    /// Canonical rule-set label: the rule names joined with `,`, plus a
    /// short FNV-1a digest of every rule's `(name, params)` sequence.
    /// The names keep provenance human-readable; the digest keeps it
    /// *precise* — two spaces that share family names but differ in
    /// configuration (`mlt-cpu` resolved on a GPU target, WMMA vs MXU
    /// tensor cores, a custom rule shadowing a builtin name with other
    /// params) stamp different labels. `--rules default` and the
    /// equivalent explicit list resolve to identical instances, hence
    /// identical labels, digest included.
    pub fn rule_set(&self) -> String {
        let names = self.rules.iter().map(|r| r.name()).collect::<Vec<_>>().join(",");
        // FNV-1a over the (name, params) sequence with field separators.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h = (h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
            }
            h = (h ^ 0x1f).wrapping_mul(0x0100_0000_01b3);
        };
        for r in &self.rules {
            eat(r.name().as_bytes());
            for (k, v) in r.params() {
                eat(k.as_bytes());
                eat(v.as_bytes());
            }
        }
        format!("{names} #{:08x}", (h ^ (h >> 32)) as u32)
    }

    /// Per-rule diagnostics accumulated across every `generate` call.
    pub fn diag(&self) -> &[RuleDiag] {
        &self.diag
    }

    /// Generate the design space for `prog`: one or more schedules whose
    /// traces are distinct linearized probabilistic programs (Figure 6).
    /// Sampling decisions inside are drawn with `seed`; the search re-draws
    /// them per population member via `replay_fresh`.
    pub fn generate(&self, prog: &Program, seed: u64) -> Vec<Schedule> {
        // Blocks in execution (pre-)order by name. Rules look blocks up by
        // name because inlining/fusion invalidates ids across variants.
        let block_names: Vec<String> = prog
            .blocks()
            .iter()
            .map(|&b| prog.block_data(b).name.clone())
            .collect();
        let mut states = vec![Schedule::new(prog.clone(), seed)];
        for name in &block_names {
            for (rule, diag) in self.rules.iter().zip(&self.diag) {
                let mut next = Vec::with_capacity(states.len());
                for sch in states.drain(..) {
                    // The block may have been inlined away in this variant.
                    if sch.prog.find_block(name).is_none() {
                        next.push(sch);
                        continue;
                    }
                    match rule.apply(sch, name, &self.target) {
                        RuleOutcome::Applied(variants) => {
                            diag.count_applied();
                            next.extend(variants);
                        }
                        RuleOutcome::Skip(s) => {
                            diag.count_skipped();
                            next.push(s);
                        }
                        RuleOutcome::Fail(s, e) => {
                            diag.count_failed(format!("{e}"));
                            next.push(s);
                        }
                    }
                }
                states = next;
            }
        }
        self.gen_calls.inc();
        self.global_gen_calls.inc();
        self.gen_states.add(states.len() as u64);
        self.global_gen_states.add(states.len() as u64);
        states
    }
}

/// Block-level analyses shared by rules.
pub mod analysis {
    use crate::tir::{IterKind, ItemId, Program};

    /// Whether the block would benefit from multi-level tiling: it is a
    /// reduction and at least one read region exhibits data reuse (some
    /// spatial iter var is absent from the region's indices — the same
    /// value is re-read across that spatial dimension).
    pub fn needs_multi_level_tiling(p: &Program, block: ItemId) -> bool {
        let bd = p.block_data(block);
        if !bd.is_reduction() {
            return false;
        }
        let spatial: Vec<_> = bd
            .iters
            .iter()
            .filter(|iv| iv.kind == IterKind::Spatial && iv.extent > 1)
            .map(|iv| iv.var)
            .collect();
        if spatial.is_empty() {
            return false;
        }
        bd.reads.iter().any(|r| {
            let mut vars = Vec::new();
            for (s, _) in &r.ranges {
                s.collect_vars(&mut vars);
            }
            spatial.iter().any(|sv| !vars.contains(sv))
        })
    }

    /// Whether the block body is a multiply-accumulate reduction (matmul-
    /// shaped), the shape `tensorize` requires.
    pub fn is_matmul_like(p: &Program, block: ItemId) -> bool {
        use crate::tir::{BinOp, BlockBody, CExpr};
        let bd = p.block_data(block);
        let mac = matches!(&bd.body, BlockBody::Reduce { op: BinOp::Add, rhs, .. }
            if matches!(rhs, CExpr::Bin(BinOp::Mul, _, _)));
        mac && bd.spatial_iters().count() >= 2 && bd.reduce_iters().count() >= 1
    }
}

#[cfg(test)]
mod tests {
    use super::analysis::*;
    use super::*;
    use crate::ctx::TuneContext;
    use crate::workloads;

    #[test]
    fn needs_mlt_matmul_yes_softmax_no() {
        let p = workloads::matmul(1, 128, 128, 128);
        let b = p.find_block("matmul").unwrap();
        assert!(needs_multi_level_tiling(&p, b));

        let s = workloads::softmax(1, 256, 256);
        let rm = s.find_block("row_max").unwrap();
        assert!(!needs_multi_level_tiling(&s, rm));
    }

    #[test]
    fn matmul_like_detection() {
        let p = workloads::matmul(1, 64, 64, 64);
        assert!(is_matmul_like(&p, p.find_block("matmul").unwrap()));
        let s = workloads::softmax(1, 64, 64);
        assert!(!is_matmul_like(&s, s.find_block("row_max").unwrap()));
        let c = workloads::conv2d(workloads::Conv2dParams::new(1, 56, 56, 16, 32, 3, 1, 1));
        assert!(is_matmul_like(&c, c.find_block("conv2d").unwrap()));
    }

    #[test]
    fn generic_space_produces_valid_schedules() {
        use crate::sim::simulate;
        for target in [Target::cpu_avx512(), Target::gpu()] {
            let prog = workloads::fused_dense(64, 128, 64);
            let ctx = TuneContext::generic(target.clone());
            let states = ctx.generate(&prog, 42);
            assert!(!states.is_empty());
            for s in &states {
                s.prog.check_integrity().unwrap();
                assert!(!s.trace.is_empty());
            }
            assert!(
                states.iter().any(|s| simulate(&s.prog, &target).is_ok()),
                "no simulatable schedule for {}",
                target.name
            );
        }
    }

    #[test]
    fn composed_space_traces_replay() {
        use crate::trace::replay;
        let prog = workloads::fused_dense(64, 128, 64);
        let ctx = TuneContext::generic(Target::cpu_avx512());
        for s in ctx.generate(&prog, 7) {
            let r = replay(&s.trace, &prog, 0).unwrap();
            assert_eq!(
                crate::tir::structural_hash(&s.prog),
                crate::tir::structural_hash(&r.prog)
            );
        }
    }

    #[test]
    fn with_tensor_core_extends_rule_list() {
        let tc = TuneContext::with_tensor_core(Target::gpu());
        let generic = TuneContext::generic(Target::gpu());
        assert!(tc.space().rules().iter().any(|m| m.name() == "use-tensor-core"));
        assert_eq!(tc.space().rules().len(), generic.space().rules().len() + 1);
        assert!(tc.rule_set().contains("use-tensor-core"));
    }

    #[test]
    fn generator_counts_rule_outcomes() {
        // On a matmul, auto-inline skips the reduction block while
        // multi-level-tiling applies — the diag counters must say so.
        let target = Target::cpu_avx512();
        let ctx = TuneContext::generic(target);
        let prog = workloads::matmul(1, 64, 64, 64);
        let states = ctx.generate(&prog, 1);
        assert!(!states.is_empty());
        let diag = ctx.space().diag();
        let by_name = |n: &str| diag.iter().find(|d| d.name() == n).unwrap();
        assert!(by_name("auto-inline").skipped() > 0);
        assert!(by_name("multi-level-tiling").applied() > 0);
    }

    #[test]
    fn rule_outcome_into_variants_passes_input_through() {
        let prog = workloads::matmul(1, 16, 16, 16);
        let sch = Schedule::new(prog, 0);
        let v = RuleOutcome::Skip(sch.clone()).into_variants();
        assert_eq!(v.len(), 1);
        let v = RuleOutcome::Fail(sch, ScheduleError::Unsupported("x".into())).into_variants();
        assert_eq!(v.len(), 1);
    }
}
