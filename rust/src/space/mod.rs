//! Composable search-space construction: transformation modules (§3.2).
//!
//! A [`TransformModule`] is the paper's *transformation module*: a named,
//! reusable unit of (program analysis + sampling + stochastic
//! transformation). The [`SpaceComposer`] composes a list of modules over
//! every block of the program (Figure 5's algorithm): visiting blocks in
//! execution order and applying each module in turn, flat-mapping over the
//! design variants each application returns. The resulting schedules carry
//! *design-space traces* — linearized probabilistic programs whose sampling
//! decisions the search later re-draws and mutates.

pub mod add_rfactor;
pub mod auto_inline;
pub mod cross_thread_reduction;
pub mod multi_level_tiling;
pub mod parallel_vectorize_unroll;
pub mod random_compute_location;
pub mod thread_bind;
pub mod use_tensor_core;

pub use add_rfactor::AddRfactor;
pub use auto_inline::AutoInline;
pub use cross_thread_reduction::CrossThreadReduction;
pub use multi_level_tiling::MultiLevelTiling;
pub use parallel_vectorize_unroll::ParallelVectorizeUnroll;
pub use random_compute_location::RandomComputeLocation;
pub use thread_bind::ThreadBind;
pub use use_tensor_core::UseTensorCore;

use crate::schedule::{SchResult, Schedule};
use crate::sim::{Target, TargetKind};
use crate::tir::Program;

/// A composable transformation module (paper §3.2, Figure 4).
///
/// `apply` receives one schedule state and the *name* of the block to
/// consider (names are stable across design variants; RV handles are not)
/// and returns the design variants it produces. Returning the input
/// unchanged (one variant) means "not applicable here". Returning more
/// than one variant forks the design space (e.g. tensorized + plain).
pub trait TransformModule {
    fn name(&self) -> &'static str;
    fn apply(&self, sch: Schedule, block_name: &str, target: &Target) -> Vec<Schedule>;
}

/// Run `f` on a clone of `sch`; keep the transformed schedule if every
/// primitive succeeded, otherwise discard it. This is the standard module
/// idiom: probe applicability by attempting the transformation.
pub fn try_transform(
    sch: &Schedule,
    f: impl FnOnce(&mut Schedule) -> SchResult<()>,
) -> Option<Schedule> {
    let mut c = sch.clone();
    match f(&mut c) {
        Ok(()) => Some(c),
        Err(_) => None,
    }
}

/// Composes transformation modules into a search space generator
/// (Figure 5 left: `Compose([m1, ..., mk])`).
pub struct SpaceComposer {
    pub modules: Vec<Box<dyn TransformModule>>,
    pub target: Target,
}

impl SpaceComposer {
    pub fn new(modules: Vec<Box<dyn TransformModule>>, target: Target) -> SpaceComposer {
        SpaceComposer { modules, target }
    }

    /// The paper's generic per-target module composition (Figure 5 right,
    /// minus hardware-specific modules).
    pub fn generic(target: Target) -> SpaceComposer {
        let modules: Vec<Box<dyn TransformModule>> = match target.kind {
            TargetKind::Cpu => vec![
                Box::new(AutoInline::new()),
                Box::new(MultiLevelTiling::cpu()),
                Box::new(AddRfactor::new()),
                Box::new(RandomComputeLocation::new()),
                Box::new(ParallelVectorizeUnroll::new()),
            ],
            TargetKind::Gpu => vec![
                Box::new(AutoInline::new()),
                Box::new(MultiLevelTiling::gpu()),
                Box::new(CrossThreadReduction::new()),
                Box::new(RandomComputeLocation::new()),
                Box::new(ThreadBind::new()),
            ],
        };
        SpaceComposer::new(modules, target)
    }

    /// Generic composition plus the hardware-specific `Use-Tensor-Core`
    /// module (Figure 5 right / Figure 10). The module is inserted after
    /// AutoInline so it claims matmul-like blocks before generic tiling.
    pub fn with_tensor_core(target: Target) -> SpaceComposer {
        let mut c = SpaceComposer::generic(target);
        c.modules.insert(1, Box::new(UseTensorCore::wmma()));
        c
    }

    /// Generate the design space for `prog`: one or more schedules whose
    /// traces are distinct linearized probabilistic programs (Figure 6).
    /// Sampling decisions inside are drawn with `seed`; the search re-draws
    /// them per population member via `replay_fresh`.
    pub fn generate(&self, prog: &Program, seed: u64) -> Vec<Schedule> {
        // Blocks in execution (pre-)order by name. Modules look blocks up by
        // name because inlining/fusion invalidates ids across variants.
        let block_names: Vec<String> = prog
            .blocks()
            .iter()
            .map(|&b| prog.block_data(b).name.clone())
            .collect();
        let mut states = vec![Schedule::new(prog.clone(), seed)];
        for name in &block_names {
            for module in &self.modules {
                let mut next = Vec::with_capacity(states.len());
                for sch in states.drain(..) {
                    // The block may have been inlined away in this variant.
                    if sch.prog.find_block(name).is_none() {
                        next.push(sch);
                        continue;
                    }
                    let variants = module.apply(sch, name, &self.target);
                    next.extend(variants);
                }
                states = next;
            }
        }
        states
    }
}

/// Block-level analyses shared by modules.
pub mod analysis {
    use crate::tir::{IterKind, ItemId, Program};

    /// Whether the block would benefit from multi-level tiling: it is a
    /// reduction and at least one read region exhibits data reuse (some
    /// spatial iter var is absent from the region's indices — the same
    /// value is re-read across that spatial dimension).
    pub fn needs_multi_level_tiling(p: &Program, block: ItemId) -> bool {
        let bd = p.block_data(block);
        if !bd.is_reduction() {
            return false;
        }
        let spatial: Vec<_> = bd
            .iters
            .iter()
            .filter(|iv| iv.kind == IterKind::Spatial && iv.extent > 1)
            .map(|iv| iv.var)
            .collect();
        if spatial.is_empty() {
            return false;
        }
        bd.reads.iter().any(|r| {
            let mut vars = Vec::new();
            for (s, _) in &r.ranges {
                s.collect_vars(&mut vars);
            }
            spatial.iter().any(|sv| !vars.contains(sv))
        })
    }

    /// Whether the block body is a multiply-accumulate reduction (matmul-
    /// shaped), the shape `tensorize` requires.
    pub fn is_matmul_like(p: &Program, block: ItemId) -> bool {
        use crate::tir::{BinOp, BlockBody, CExpr};
        let bd = p.block_data(block);
        let mac = matches!(&bd.body, BlockBody::Reduce { op: BinOp::Add, rhs, .. }
            if matches!(rhs, CExpr::Bin(BinOp::Mul, _, _)));
        mac && bd.spatial_iters().count() >= 2 && bd.reduce_iters().count() >= 1
    }
}

#[cfg(test)]
mod tests {
    use super::analysis::*;
    use super::*;
    use crate::workloads;

    #[test]
    fn needs_mlt_matmul_yes_softmax_no() {
        let p = workloads::matmul(1, 128, 128, 128);
        let b = p.find_block("matmul").unwrap();
        assert!(needs_multi_level_tiling(&p, b));

        let s = workloads::softmax(1, 256, 256);
        let rm = s.find_block("row_max").unwrap();
        assert!(!needs_multi_level_tiling(&s, rm));
    }

    #[test]
    fn matmul_like_detection() {
        let p = workloads::matmul(1, 64, 64, 64);
        assert!(is_matmul_like(&p, p.find_block("matmul").unwrap()));
        let s = workloads::softmax(1, 64, 64);
        assert!(!is_matmul_like(&s, s.find_block("row_max").unwrap()));
        let c = workloads::conv2d(workloads::Conv2dParams::new(1, 56, 56, 16, 32, 3, 1, 1));
        assert!(is_matmul_like(&c, c.find_block("conv2d").unwrap()));
    }

    #[test]
    fn generic_composer_produces_valid_schedules() {
        use crate::sim::simulate;
        for target in [Target::cpu_avx512(), Target::gpu()] {
            let prog = workloads::fused_dense(64, 128, 64);
            let composer = SpaceComposer::generic(target.clone());
            let states = composer.generate(&prog, 42);
            assert!(!states.is_empty());
            for s in &states {
                s.prog.check_integrity().unwrap();
                assert!(!s.trace.is_empty());
            }
            assert!(
                states.iter().any(|s| simulate(&s.prog, &target).is_ok()),
                "no simulatable schedule for {}",
                target.name
            );
        }
    }

    #[test]
    fn composed_space_traces_replay() {
        use crate::trace::replay;
        let prog = workloads::fused_dense(64, 128, 64);
        let composer = SpaceComposer::generic(Target::cpu_avx512());
        for s in composer.generate(&prog, 7) {
            let r = replay(&s.trace, &prog, 0).unwrap();
            assert_eq!(
                crate::tir::structural_hash(&s.prog),
                crate::tir::structural_hash(&r.prog)
            );
        }
    }

    #[test]
    fn with_tensor_core_extends_module_list() {
        let c = SpaceComposer::with_tensor_core(Target::gpu());
        assert!(c.modules.iter().any(|m| m.name() == "use-tensor-core"));
        assert_eq!(c.modules.len(), SpaceComposer::generic(Target::gpu()).modules.len() + 1);
    }
}
