//! Cross-Thread-Reduction: GPU module for reduction blocks. Binds the
//! spatial loops to the grid and a sampled slice of the fused reduction
//! loop to `threadIdx.x`, so the reduction runs as a cross-thread tree
//! (the simulator charges log2 rounds of synchronization).

use crate::schedule::{LoopRv, SchResult, Schedule};
use crate::sim::Target;
use crate::space::{attempt, RuleOutcome, ScheduleRule};
use crate::tir::analysis::{classify_loop, LoopClass};
use crate::tir::LoopKind;
use crate::trace::FactorArg;

pub struct CrossThreadReduction;

impl CrossThreadReduction {
    pub fn new() -> CrossThreadReduction {
        CrossThreadReduction
    }

    fn transform(&self, s: &mut Schedule, block_name: &str) -> SchResult<()> {
        let b = s.get_block(block_name)?;
        let loops = s.get_loops(b)?;
        let mut spatial: Vec<LoopRv> = Vec::new();
        let mut reduce: Vec<LoopRv> = Vec::new();
        for &l in &loops {
            let item = s.loop_item(l)?;
            if s.prog.loop_data(item).kind != LoopKind::Serial {
                return Err(crate::schedule::ScheduleError::WrongLoopKind(
                    "cross-thread reduction requires serial loops".into(),
                ));
            }
            match classify_loop(&s.prog, item) {
                LoopClass::Spatial => spatial.push(l),
                LoopClass::Reduce => reduce.push(l),
                LoopClass::Unused => {}
                LoopClass::Mixed => {
                    return Err(crate::schedule::ScheduleError::Unsupported(
                        "mixed loop".into(),
                    ))
                }
            }
        }
        if reduce.is_empty() {
            return Err(crate::schedule::ScheduleError::NotReduction(
                "no reduction loops".into(),
            ));
        }
        // Grid: fused spatial loops -> blockIdx.x (or a fresh unit loop for
        // a full reduction with no spatial extent).
        let grid = if spatial.is_empty() {
            s.add_unit_loop(b)?
        } else if spatial.len() > 1 {
            s.fuse(&spatial)?
        } else {
            spatial[0]
        };
        s.bind(grid, "blockIdx.x")?;
        // Threads: fused reduction loop, split with sampled factors; the
        // inner part becomes the cross-thread extent.
        let r = if reduce.len() > 1 { s.fuse(&reduce)? } else { reduce[0] };
        let t = s.sample_perfect_tile(r, 2, 1024)?;
        let parts = s.split(r, &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])?;
        s.bind(parts[1], "threadIdx.x")?;
        Ok(())
    }
}

impl Default for CrossThreadReduction {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleRule for CrossThreadReduction {
    fn name(&self) -> &str {
        "cross-thread-reduction"
    }

    fn describe(&self) -> String {
        "bind plain reductions as a cross-thread tree (grid spatial, threadIdx slice)".into()
    }

    fn apply(&self, sch: Schedule, block_name: &str, _target: &Target) -> RuleOutcome {
        let applicable = sch
            .prog
            .find_block(block_name)
            .map(|b| {
                let bd = sch.prog.block_data(b);
                // Only plain reductions; multi-level-tiled matmuls are
                // handled by their own module (their loops are not serial
                // any more, which `transform` would reject anyway).
                bd.is_reduction() && !crate::space::analysis::needs_multi_level_tiling(&sch.prog, b)
            })
            .unwrap_or(false);
        if !applicable {
            return RuleOutcome::Skip(sch);
        }
        match attempt(&sch, |s| self.transform(s, block_name)) {
            Ok(out) => RuleOutcome::Applied(vec![out, sch]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, Target};
    use crate::workloads;

    #[test]
    fn softmax_row_sum_gets_cross_thread_binding() {
        let t = Target::gpu();
        let m = CrossThreadReduction::new();
        let prog = workloads::softmax(1, 256, 256);
        let variants = m.apply(Schedule::new(prog, 2), "row_sum", &t).into_variants();
        assert_eq!(variants.len(), 2);
        let xt = &variants[0];
        xt.prog.check_integrity().unwrap();
        let has_tx = xt
            .prog
            .preorder()
            .into_iter()
            .filter(|&i| xt.prog.is_loop(i))
            .any(|i| matches!(&xt.prog.loop_data(i).kind,
                LoopKind::ThreadBinding(t) if t == "threadIdx.x"));
        assert!(has_tx);
        // And it must simulate (thread extent within limits for this seed
        // or rejected by sim — across seeds at least one must pass).
        let ok = (0..8).any(|seed| {
            let prog = workloads::softmax(1, 256, 256);
            let v = m.apply(Schedule::new(prog, seed), "row_sum", &t).into_variants();
            simulate(&v[0].prog, &t).is_ok()
        });
        assert!(ok);
    }

    #[test]
    fn matmul_left_to_multi_level_tiling() {
        let t = Target::gpu();
        let m = CrossThreadReduction::new();
        let prog = workloads::matmul(1, 128, 128, 128);
        let variants = m.apply(Schedule::new(prog, 2), "matmul", &t).into_variants();
        assert_eq!(variants.len(), 1);
        assert!(variants[0].trace.is_empty());
    }
}
