//! Multi-Level-Tiling: the paper's Figure 4 example module.
//!
//! Analysis identifies the spatial (data-parallel) and reduction loops of a
//! compute-intensive block; each spatial loop is split into `spatial_parts`
//! tiles and each reduction loop into `reduce_parts` tiles with factors
//! drawn from `Sample-Tile` (`sample_perfect_tile`); a final `Reorder`
//! interleaves the tile levels into the classic cache-blocking structure —
//! `SSRSRS` on CPU, `SSSRRSRS` with thread bindings on GPU.

use crate::schedule::{LoopRv, SchResult, Schedule};
use crate::sim::Target;
use crate::space::{analysis::needs_multi_level_tiling, attempt, RuleOutcome, ScheduleRule};
use crate::tir::analysis::{classify_loop, LoopClass};
use crate::tir::LoopKind;
use crate::trace::FactorArg;

/// One level of the tiling structure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// k-th spatial tile level, optionally fused & bound to a thread axis.
    Spatial(usize, Option<&'static str>),
    /// k-th reduction tile level.
    Reduce(usize),
}

pub struct MultiLevelTiling {
    pub structure_name: &'static str,
    pub spatial_parts: usize,
    pub reduce_parts: usize,
    pub max_innermost: i64,
    /// Tile-level interleaving, outermost first.
    pub levels: Vec<Level>,
}

impl MultiLevelTiling {
    /// CPU `SSRSRS`: 4-way spatial x 2-way reduction cache blocking.
    pub fn cpu() -> MultiLevelTiling {
        MultiLevelTiling {
            structure_name: "SSRSRS",
            spatial_parts: 4,
            reduce_parts: 2,
            max_innermost: 64,
            levels: vec![
                Level::Spatial(0, None),
                Level::Spatial(1, None),
                Level::Reduce(0),
                Level::Spatial(2, None),
                Level::Reduce(1),
                Level::Spatial(3, None),
            ],
        }
    }

    /// GPU `SSSRRSRS`: 5-way spatial x 3-way reduction; the outermost fused
    /// spatial level binds to `blockIdx.x`, the third to `threadIdx.x`.
    pub fn gpu() -> MultiLevelTiling {
        MultiLevelTiling {
            structure_name: "SSSRRSRS",
            spatial_parts: 5,
            reduce_parts: 3,
            max_innermost: 64,
            levels: vec![
                Level::Spatial(0, Some("blockIdx.x")),
                Level::Spatial(1, None),
                Level::Spatial(2, Some("threadIdx.x")),
                Level::Reduce(0),
                Level::Reduce(1),
                Level::Spatial(3, None),
                Level::Reduce(2),
                Level::Spatial(4, None),
            ],
        }
    }

    fn tile(&self, s: &mut Schedule, block_name: &str) -> SchResult<()> {
        let b = s.get_block(block_name)?;
        let loops = s.get_loops(b)?;
        // Interactive analysis: classify loops against the *current* state.
        let mut spatial = Vec::new();
        let mut reduce = Vec::new();
        for &l in &loops {
            let item = s.loop_item(l)?;
            if s.prog.loop_data(item).kind != LoopKind::Serial {
                return Err(crate::schedule::ScheduleError::WrongLoopKind(
                    "multi-level tiling requires serial loops".into(),
                ));
            }
            let extent = s.prog.loop_data(item).extent;
            match classify_loop(&s.prog, item) {
                // Extent-1 loops (e.g. batch) stay where they are.
                LoopClass::Spatial if extent > 1 => spatial.push(l),
                LoopClass::Reduce if extent > 1 => reduce.push(l),
                LoopClass::Spatial | LoopClass::Reduce | LoopClass::Unused => {}
                LoopClass::Mixed => {
                    return Err(crate::schedule::ScheduleError::Unsupported(
                        "mixed loop under multi-level tiling".into(),
                    ))
                }
            }
        }
        if spatial.is_empty() || reduce.is_empty() {
            return Err(crate::schedule::ScheduleError::Unsupported(
                "multi-level tiling needs spatial and reduction loops".into(),
            ));
        }
        // Stochastic tiling: Sample-Tile then Split, per loop.
        let mut s_tiles: Vec<Vec<LoopRv>> = Vec::with_capacity(spatial.len());
        for &l in &spatial {
            let t = s.sample_perfect_tile(l, self.spatial_parts, self.max_innermost)?;
            let factors: Vec<FactorArg> = t.iter().map(|rv| FactorArg::Rv(rv.0)).collect();
            s_tiles.push(s.split(l, &factors)?);
        }
        let mut r_tiles: Vec<Vec<LoopRv>> = Vec::with_capacity(reduce.len());
        for &l in &reduce {
            let t = s.sample_perfect_tile(l, self.reduce_parts, 0)?;
            let factors: Vec<FactorArg> = t.iter().map(|rv| FactorArg::Rv(rv.0)).collect();
            r_tiles.push(s.split(l, &factors)?);
        }
        // Reorder into the tile structure.
        let mut order: Vec<LoopRv> = Vec::new();
        for lv in &self.levels {
            match lv {
                Level::Spatial(k, _) => order.extend(s_tiles.iter().map(|t| t[*k])),
                Level::Reduce(k) => order.extend(r_tiles.iter().map(|t| t[*k])),
            }
        }
        s.reorder(&order)?;
        // Fuse + bind the annotated levels (outermost first so fusion does
        // not disturb inner chains).
        for lv in &self.levels {
            if let Level::Spatial(k, Some(axis)) = lv {
                let group: Vec<LoopRv> = s_tiles.iter().map(|t| t[*k]).collect();
                let fused = if group.len() > 1 { s.fuse(&group)? } else { group[0] };
                s.bind(fused, axis)?;
            }
        }
        Ok(())
    }
}

impl ScheduleRule for MultiLevelTiling {
    fn name(&self) -> &str {
        "multi-level-tiling"
    }

    fn describe(&self) -> String {
        format!(
            "{} cache blocking with Sample-Tile factors on reuse-bearing reductions",
            self.structure_name
        )
    }

    fn params(&self) -> Vec<(String, String)> {
        vec![
            ("structure".into(), self.structure_name.to_string()),
            ("spatial-parts".into(), self.spatial_parts.to_string()),
            ("reduce-parts".into(), self.reduce_parts.to_string()),
            ("max-innermost".into(), self.max_innermost.to_string()),
        ]
    }

    fn apply(&self, sch: Schedule, block_name: &str, _target: &Target) -> RuleOutcome {
        let applicable = sch
            .prog
            .find_block(block_name)
            .map(|b| needs_multi_level_tiling(&sch.prog, b))
            .unwrap_or(false);
        if !applicable {
            return RuleOutcome::Skip(sch);
        }
        match attempt(&sch, |s| self.tile(s, block_name)) {
            Ok(tiled) => RuleOutcome::Applied(vec![tiled]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, Target};
    use crate::tir::analysis::{loop_count, program_flops};
    use crate::workloads;

    #[test]
    fn cpu_tiling_builds_ssrsrs() {
        let prog = workloads::matmul(1, 64, 64, 64);
        let before = program_flops(&prog);
        let m = MultiLevelTiling::cpu();
        let out = m
            .apply(Schedule::new(prog, 3), "matmul", &Target::cpu_avx512())
            .into_variants()
            .pop()
            .unwrap();
        out.prog.check_integrity().unwrap();
        assert_eq!(program_flops(&out.prog), before);
        // b(unit) stays + i,j split into 4 + k split into 2 => 1 + 8 + 2.
        assert_eq!(loop_count(&out.prog), 11);
        // Trace contains three sampling instructions.
        assert_eq!(out.trace.sampling_indices().len(), 3);
    }

    #[test]
    fn gpu_tiling_binds_threads() {
        let prog = workloads::matmul(1, 64, 64, 64);
        let m = MultiLevelTiling::gpu();
        let out = m
            .apply(Schedule::new(prog, 3), "matmul", &Target::gpu())
            .into_variants()
            .pop()
            .unwrap();
        out.prog.check_integrity().unwrap();
        let binds: Vec<String> = out
            .prog
            .preorder()
            .into_iter()
            .filter(|&i| out.prog.is_loop(i))
            .filter_map(|i| match &out.prog.loop_data(i).kind {
                crate::tir::LoopKind::ThreadBinding(t) => Some(t.clone()),
                _ => None,
            })
            .collect();
        assert!(binds.contains(&"blockIdx.x".to_string()));
        assert!(binds.contains(&"threadIdx.x".to_string()));
    }

    #[test]
    fn elementwise_block_not_tiled() {
        let prog = workloads::relu(4096);
        let m = MultiLevelTiling::cpu();
        let out = m
            .apply(Schedule::new(prog.clone(), 3), "relu", &Target::cpu_avx512())
            .into_variants()
            .pop()
            .unwrap();
        assert_eq!(loop_count(&out.prog), 1); // untouched
        assert!(out.trace.is_empty());
    }

    #[test]
    fn tiling_plus_pvu_beats_naive_on_sim() {
        // Tiling alone pays loop-entry overhead without using more of the
        // machine; composed with parallel+vectorize (the realistic
        // pipeline) the best-of-seeds schedule must win big.
        use crate::space::{ParallelVectorizeUnroll, ScheduleRule};
        let t = Target::cpu_avx512();
        let prog = workloads::matmul(1, 512, 512, 512);
        let naive = simulate(&prog, &t).unwrap().total_s;
        let mlt = MultiLevelTiling::cpu();
        let pvu = ParallelVectorizeUnroll::new();
        let best = (0..8)
            .filter_map(|seed| {
                let out = mlt
                    .apply(Schedule::new(prog.clone(), seed), "matmul", &t)
                    .into_variants()
                    .pop()
                    .unwrap();
                let out = pvu.apply(out, "matmul", &t).into_variants().pop().unwrap();
                simulate(&out.prog, &t).ok().map(|r| r.total_s)
            })
            .fold(f64::INFINITY, f64::min);
        assert!(best < naive / 5.0, "best tiled+pvu {best} vs naive {naive}");
        // And tiling alone stays within overhead noise of naive.
        let tiled_only = (0..8)
            .filter_map(|seed| {
                let out = mlt
                    .apply(Schedule::new(prog.clone(), seed), "matmul", &t)
                    .into_variants()
                    .pop()
                    .unwrap();
                simulate(&out.prog, &t).ok().map(|r| r.total_s)
            })
            .fold(f64::INFINITY, f64::min);
        assert!(tiled_only <= naive * 1.6, "tiled {tiled_only} vs naive {naive}");
    }

    #[test]
    fn conv_workloads_tile_cleanly() {
        let t = Target::cpu_avx512();
        let m = MultiLevelTiling::cpu();
        for name in ["C2D", "DEP", "GRP"] {
            let w = workloads::by_name(name).unwrap();
            let prog = (w.build)();
            let bname = prog.blocks().first().map(|&b| prog.block_data(b).name.clone()).unwrap();
            let out = m.apply(Schedule::new(prog, 5), &bname, &t).into_variants().pop().unwrap();
            out.prog.check_integrity().unwrap();
            assert!(!out.trace.is_empty(), "{name} did not tile");
        }
    }
}
