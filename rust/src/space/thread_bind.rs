//! Thread-Bind: GPU fallback module. Any block left without thread
//! bindings (elementwise copies, normalization stages, ...) gets its
//! leading spatial loops fused, split by sampled factors, and bound to
//! `blockIdx.x` / `threadIdx.x` — without this every unbound kernel would
//! serialize on one GPU thread.

use crate::schedule::{LoopRv, SchResult, Schedule};
use crate::sim::Target;
use crate::space::{attempt, RuleOutcome, ScheduleRule};
use crate::tir::analysis::{classify_loop, LoopClass};
use crate::tir::LoopKind;
use crate::trace::FactorArg;

pub struct ThreadBind {
    pub max_threads: i64,
}

impl ThreadBind {
    pub fn new() -> ThreadBind {
        ThreadBind { max_threads: 1024 }
    }

    fn transform(&self, s: &mut Schedule, block_name: &str) -> SchResult<()> {
        let b = s.get_block(block_name)?;
        let loops = s.get_loops(b)?;
        // Leading run of serial spatial loops.
        let mut run: Vec<LoopRv> = Vec::new();
        for &l in &loops {
            let item = s.loop_item(l)?;
            let ld = s.prog.loop_data(item);
            let cls = classify_loop(&s.prog, item);
            if ld.kind != LoopKind::Serial
                || !(cls == LoopClass::Spatial || cls == LoopClass::Unused)
            {
                break;
            }
            run.push(l);
        }
        if run.is_empty() {
            return Err(crate::schedule::ScheduleError::Unsupported(
                "no spatial loops to bind".into(),
            ));
        }
        let fused = if run.len() > 1 { s.fuse(&run)? } else { run[0] };
        let extent = s.prog.loop_data(s.loop_item(fused)?).extent;
        if extent == 1 {
            s.bind(fused, "blockIdx.x")?;
            return Ok(());
        }
        let t = s.sample_perfect_tile(fused, 2, self.max_threads)?;
        let parts = s.split(fused, &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])?;
        s.bind(parts[0], "blockIdx.x")?;
        s.bind(parts[1], "threadIdx.x")?;
        Ok(())
    }
}

impl Default for ThreadBind {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleRule for ThreadBind {
    fn name(&self) -> &str {
        "thread-bind"
    }

    fn describe(&self) -> String {
        "fallback GPU binding: fuse + split leading spatial loops onto the grid".into()
    }

    fn params(&self) -> Vec<(String, String)> {
        vec![("max-threads".into(), self.max_threads.to_string())]
    }

    fn apply(&self, sch: Schedule, block_name: &str, _target: &Target) -> RuleOutcome {
        // Skip blocks that already have any thread binding above them.
        let unbound = sch
            .prog
            .find_block(block_name)
            .map(|b| {
                sch.prog.loops_above(b).iter().all(|&l| {
                    !matches!(sch.prog.loop_data(l).kind, LoopKind::ThreadBinding(_))
                })
            })
            .unwrap_or(false);
        if !unbound {
            return RuleOutcome::Skip(sch);
        }
        match attempt(&sch, |s| self.transform(s, block_name)) {
            Ok(out) => RuleOutcome::Applied(vec![out]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, Target};
    use crate::workloads;

    fn bound_axes(s: &Schedule) -> Vec<String> {
        s.prog
            .preorder()
            .into_iter()
            .filter(|&i| s.prog.is_loop(i))
            .filter_map(|i| match &s.prog.loop_data(i).kind {
                LoopKind::ThreadBinding(t) => Some(t.clone()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn binds_elementwise_block() {
        let t = Target::gpu();
        let m = ThreadBind::new();
        let prog = workloads::relu(1 << 20);
        let out = m.apply(Schedule::new(prog.clone(), 4), "relu", &t).into_variants().pop().unwrap();
        let axes = bound_axes(&out);
        assert!(axes.contains(&"blockIdx.x".to_string()));
        // Bound kernel is far faster than the unbound one on the GPU model.
        let base = simulate(&prog, &t).unwrap().total_s;
        let best = (0..8)
            .filter_map(|seed| {
                let prog = workloads::relu(1 << 20);
                let o = m.apply(Schedule::new(prog, seed), "relu", &t).into_variants().pop().unwrap();
                simulate(&o.prog, &t).ok().map(|r| r.total_s)
            })
            .fold(f64::INFINITY, f64::min);
        assert!(best < base * 0.05, "best {best} vs base {base}");
    }

    #[test]
    fn skips_already_bound_blocks() {
        let t = Target::gpu();
        let m = ThreadBind::new();
        let prog = workloads::relu(1024);
        let mut s = Schedule::new(prog, 0);
        let b = s.get_block("relu").unwrap();
        let loops = s.get_loops(b).unwrap();
        s.bind(loops[0], "threadIdx.x").unwrap();
        let len = s.trace.len();
        let out = m.apply(s, "relu", &t).into_variants().pop().unwrap();
        assert_eq!(out.trace.len(), len); // untouched
    }
}
