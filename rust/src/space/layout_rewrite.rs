//! Layout-Rewrite: propose data-layout transforms on matmul-class anchor
//! blocks (the graph-fusion subsystem's schedule-space counterpart; TVM's
//! `RewriteLayout` / Ansor's layout rewrite).
//!
//! Applicability analysis: a read of a matmul-like block is
//! *layout-hostile* when every index is a plain block iter var and the
//! block's innermost spatial iter indexes a non-innermost buffer
//! dimension — the innermost loop then strides through memory (e.g.
//! `dense`'s `W[j, k]` with `j` innermost-spatial strides by `k`). The
//! rule repacks that buffer with `transform-layout` so the hot dimension
//! lands last, and forks the space (rewritten + original) so the search
//! decides whether the pack pays for itself.

use crate::schedule::Schedule;
use crate::sim::Target;
use crate::space::{analysis::is_matmul_like, attempt, RuleOutcome, ScheduleRule};
use crate::tir::{AExpr, IterKind, Program};

#[derive(Default)]
pub struct LayoutRewrite;

impl LayoutRewrite {
    pub fn new() -> LayoutRewrite {
        LayoutRewrite
    }

    /// The first layout-hostile read of `block`, as `(read_idx, perm)`:
    /// `perm` moves the dimension indexed by the innermost spatial iter
    /// to the last position, preserving the order of the rest.
    fn hostile_read(prog: &Program, block: crate::tir::ItemId) -> Option<(usize, Vec<usize>)> {
        let bd = prog.block_data(block);
        let innermost = bd
            .iters
            .iter()
            .filter(|iv| iv.kind == IterKind::Spatial && iv.extent > 1)
            .map(|iv| iv.var)
            .last()?;
        for (ri, r) in bd.reads.iter().enumerate() {
            let vars: Option<Vec<_>> = r
                .ranges
                .iter()
                .map(|(e, _)| match e {
                    AExpr::Var(v) => Some(*v),
                    _ => None,
                })
                .collect();
            let Some(vars) = vars else { continue };
            let rank = vars.len();
            if rank < 2 {
                continue;
            }
            if let Some(p) = vars.iter().position(|&v| v == innermost) {
                if p + 1 != rank {
                    let mut perm: Vec<usize> = (0..rank).filter(|&d| d != p).collect();
                    perm.push(p);
                    return Some((ri, perm));
                }
            }
        }
        None
    }
}

impl ScheduleRule for LayoutRewrite {
    fn name(&self) -> &str {
        "layout-rewrite"
    }

    fn describe(&self) -> String {
        "repack layout-hostile reads of matmul-like blocks so the innermost spatial dim is contiguous, forking rewritten + original".into()
    }

    fn apply(&self, sch: Schedule, block_name: &str, _target: &Target) -> RuleOutcome {
        let hostile = sch
            .prog
            .find_block(block_name)
            .filter(|&b| is_matmul_like(&sch.prog, b))
            .and_then(|b| Self::hostile_read(&sch.prog, b));
        let Some((read_idx, perm)) = hostile else {
            return RuleOutcome::Skip(sch);
        };
        match attempt(&sch, |s| {
            let b = s.get_block(block_name)?;
            s.transform_layout(b, read_idx, &perm).map(|_| ())
        }) {
            Ok(out) => RuleOutcome::Applied(vec![out, sch]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Target;
    use crate::workloads;

    #[test]
    fn rewrites_dense_weight_read() {
        let t = Target::cpu_avx512();
        let r = LayoutRewrite::new();
        let prog = workloads::dense(64, 64, 128);
        let variants = r.apply(Schedule::new(prog, 0), "dense", &t).into_variants();
        assert_eq!(variants.len(), 2);
        let rewritten = &variants[0];
        rewritten.prog.check_integrity().unwrap();
        assert!(rewritten
            .prog
            .buffers
            .iter()
            .any(|b| b.name == "W_layout" && b.shape == vec![128, 64]));
        // The fork keeps the original untouched.
        assert!(variants[1].trace.is_empty());
    }

    #[test]
    fn attention_scores_k_read_is_hostile() {
        let t = Target::cpu_avx512();
        let r = LayoutRewrite::new();
        let prog = workloads::attention(32, 4, 16);
        let variants = r.apply(Schedule::new(prog, 0), "scores", &t).into_variants();
        assert_eq!(variants.len(), 2);
        // K[j,h,d] with innermost spatial j -> K_layout[h,d,j].
        assert!(variants[0]
            .prog
            .buffers
            .iter()
            .any(|b| b.name == "K_layout" && b.shape == vec![4, 16, 32]));
    }

    #[test]
    fn gmm_and_injective_blocks_skip() {
        let t = Target::cpu_avx512();
        let r = LayoutRewrite::new();
        // GMM's B[b,k,j] already has j innermost: nothing to rewrite.
        let prog = workloads::matmul(1, 64, 64, 64);
        let v = r.apply(Schedule::new(prog, 0), "matmul", &t).into_variants();
        assert_eq!(v.len(), 1);
        assert!(v[0].trace.is_empty());
        // Non-matmul blocks skip outright.
        let prog = workloads::relu(64);
        let v = r.apply(Schedule::new(prog, 0), "relu", &t).into_variants();
        assert_eq!(v.len(), 1);
    }

    #[test]
    fn rewritten_variant_replays() {
        let t = Target::cpu_avx512();
        let r = LayoutRewrite::new();
        let prog = workloads::dense(64, 64, 128);
        let v = r.apply(Schedule::new(prog.clone(), 0), "dense", &t).into_variants();
        let replayed = crate::trace::replay(&v[0].trace, &prog, 0).unwrap();
        assert_eq!(
            crate::tir::structural_hash(&replayed.prog),
            crate::tir::structural_hash(&v[0].prog)
        );
    }
}
