//! Per-rule applicability/error counters (the data behind
//! `tune --explain-space`).
//!
//! A [`RuleDiag`] accumulates across every `generate` call made through
//! one [`crate::space::SpaceGenerator`] — atomics, because the task
//! scheduler shares one generator across worker threads. The counters are
//! diagnostics only: they never feed back into the search, so recording
//! them cannot perturb the determinism contract.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Distinct error messages retained per rule (the count is always exact).
const MAX_ERROR_NOTES: usize = 4;

/// Counters for one rule: how often it applied, skipped (its own
/// applicability analysis said no), or failed structurally (considered
/// itself applicable but the transformation errored).
#[derive(Debug)]
pub struct RuleDiag {
    name: String,
    applied: AtomicUsize,
    skipped: AtomicUsize,
    failed: AtomicUsize,
    errors: Mutex<Vec<String>>,
}

impl RuleDiag {
    pub(crate) fn new(name: &str) -> RuleDiag {
        RuleDiag {
            name: name.to_string(),
            applied: AtomicUsize::new(0),
            skipped: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            errors: Mutex::new(Vec::new()),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn applied(&self) -> usize {
        self.applied.load(Ordering::Relaxed)
    }

    pub fn skipped(&self) -> usize {
        self.skipped.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> usize {
        self.failed.load(Ordering::Relaxed)
    }

    /// The first few *distinct* error messages seen (capped; the
    /// `failed` count stays exact).
    pub fn errors(&self) -> Vec<String> {
        self.errors.lock().unwrap().clone()
    }

    pub(crate) fn count_applied(&self) {
        self.applied.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn count_skipped(&self) {
        self.skipped.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn count_failed(&self, msg: String) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        let mut errs = self.errors.lock().unwrap();
        if errs.len() < MAX_ERROR_NOTES && !errs.contains(&msg) {
            errs.push(msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_errors_dedup() {
        let d = RuleDiag::new("r");
        d.count_applied();
        d.count_skipped();
        d.count_skipped();
        for _ in 0..10 {
            d.count_failed("same error".into());
        }
        d.count_failed("other error".into());
        assert_eq!(d.name(), "r");
        assert_eq!(d.applied(), 1);
        assert_eq!(d.skipped(), 2);
        assert_eq!(d.failed(), 11, "count stays exact past the note cap");
        assert_eq!(d.errors(), vec!["same error".to_string(), "other error".to_string()]);
    }
}
