//! Per-rule applicability/error counters (the data behind
//! `tune --explain-space`).
//!
//! A [`RuleDiag`] accumulates across every `generate` call made through
//! one [`crate::space::SpaceGenerator`]. The counts are backed by
//! [`crate::telemetry::Counter`]s registered in the generator's own
//! [`crate::telemetry::Metrics`] registry (per-generator, *not* the
//! process-global one: `--explain-space` tests assert exact counts, and
//! contexts running concurrently — parallel `cargo test`, the task
//! scheduler — must not bleed into each other). The counters are
//! diagnostics only: they never feed back into the search, so recording
//! them cannot perturb the determinism contract.

use std::sync::{Arc, Mutex};

use crate::telemetry::{sanitize_name, Counter, Metrics};

/// Distinct error messages retained per rule (the count is always exact).
const MAX_ERROR_NOTES: usize = 4;

/// Counters for one rule: how often it applied, skipped (its own
/// applicability analysis said no), or failed structurally (considered
/// itself applicable but the transformation errored).
#[derive(Debug)]
pub struct RuleDiag {
    name: String,
    applied: Arc<Counter>,
    skipped: Arc<Counter>,
    failed: Arc<Counter>,
    errors: Mutex<Vec<String>>,
}

impl RuleDiag {
    /// Register this rule's counters in `metrics` as
    /// `space_rule_<name>_{applied,skipped,failed}_total` (name
    /// sanitized; collisions — the same rule twice in one space — get a
    /// `_2` suffix rather than sharing counts).
    pub(crate) fn new(name: &str, metrics: &Metrics) -> RuleDiag {
        let frag = sanitize_name(name);
        RuleDiag {
            name: name.to_string(),
            applied: metrics.counter_unique(
                &format!("space_rule_{frag}_applied_total"),
                "rule applications that transformed the schedule",
            ),
            skipped: metrics.counter_unique(
                &format!("space_rule_{frag}_skipped_total"),
                "rule applications whose applicability analysis said no",
            ),
            failed: metrics.counter_unique(
                &format!("space_rule_{frag}_failed_total"),
                "rule applications that errored structurally",
            ),
            errors: Mutex::new(Vec::new()),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn applied(&self) -> usize {
        self.applied.get() as usize
    }

    pub fn skipped(&self) -> usize {
        self.skipped.get() as usize
    }

    pub fn failed(&self) -> usize {
        self.failed.get() as usize
    }

    /// The first few *distinct* error messages seen (capped; the
    /// `failed` count stays exact).
    pub fn errors(&self) -> Vec<String> {
        self.errors.lock().unwrap().clone()
    }

    pub(crate) fn count_applied(&self) {
        self.applied.inc();
    }

    pub(crate) fn count_skipped(&self) {
        self.skipped.inc();
    }

    pub(crate) fn count_failed(&self, msg: String) {
        self.failed.inc();
        let mut errs = self.errors.lock().unwrap();
        if errs.len() < MAX_ERROR_NOTES && !errs.contains(&msg) {
            errs.push(msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_errors_dedup() {
        let m = Metrics::new();
        let d = RuleDiag::new("r", &m);
        d.count_applied();
        d.count_skipped();
        d.count_skipped();
        for _ in 0..10 {
            d.count_failed("same error".into());
        }
        d.count_failed("other error".into());
        assert_eq!(d.name(), "r");
        assert_eq!(d.applied(), 1);
        assert_eq!(d.skipped(), 2);
        assert_eq!(d.failed(), 11, "count stays exact past the note cap");
        assert_eq!(d.errors(), vec!["same error".to_string(), "other error".to_string()]);
        // The counts are visible through the registry under sanitized names.
        assert_eq!(m.counter_value("space_rule_r_applied_total"), Some(1));
        assert_eq!(m.counter_value("space_rule_r_failed_total"), Some(11));
    }

    #[test]
    fn duplicate_rule_names_do_not_share_counters() {
        let m = Metrics::new();
        let a = RuleDiag::new("auto-inline", &m);
        let b = RuleDiag::new("auto-inline", &m);
        a.count_applied();
        a.count_applied();
        b.count_applied();
        assert_eq!(a.applied(), 2);
        assert_eq!(b.applied(), 1, "second instance must keep its own counts");
    }
}
