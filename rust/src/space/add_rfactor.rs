//! Add-Rfactor: CPU module for reduction blocks with too little spatial
//! parallelism. Splits a reduction loop with sampled factors and
//! `rfactor`s the block so the partial sums can run across cores.

use crate::schedule::{SchResult, Schedule};
use crate::sim::Target;
use crate::space::{attempt, RuleOutcome, ScheduleRule};
use crate::tir::analysis::{classify_loop, LoopClass};
use crate::tir::LoopKind;
use crate::trace::FactorArg;

pub struct AddRfactor {
    /// Apply only when the spatial trip count is below
    /// `cores * jobs_per_core` (otherwise plain parallelism suffices).
    pub jobs_per_core: i64,
}

impl AddRfactor {
    pub fn new() -> AddRfactor {
        AddRfactor { jobs_per_core: 2 }
    }

    fn transform(&self, s: &mut Schedule, block_name: &str) -> SchResult<()> {
        let b = s.get_block(block_name)?;
        let loops = s.get_loops(b)?;
        // Find the first serial reduction loop with a meaningful extent.
        let mut target_loop = None;
        for &l in &loops {
            let item = s.loop_item(l)?;
            if s.prog.loop_data(item).kind == LoopKind::Serial
                && classify_loop(&s.prog, item) == LoopClass::Reduce
                && s.prog.loop_data(item).extent >= 16
            {
                target_loop = Some(l);
                break;
            }
        }
        let l = target_loop.ok_or(crate::schedule::ScheduleError::NotReduction(
            "no reduction loop to rfactor".into(),
        ))?;
        let t = s.sample_perfect_tile(l, 2, 0)?;
        let parts = s.split(l, &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)])?;
        s.rfactor(b, parts[0])?;
        Ok(())
    }
}

impl Default for AddRfactor {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleRule for AddRfactor {
    fn name(&self) -> &str {
        "add-rfactor"
    }

    fn describe(&self) -> String {
        "rfactor reduction blocks with too little spatial parallelism across cores".into()
    }

    fn params(&self) -> Vec<(String, String)> {
        vec![("jobs-per-core".into(), self.jobs_per_core.to_string())]
    }

    fn apply(&self, sch: Schedule, block_name: &str, target: &Target) -> RuleOutcome {
        let applicable = sch
            .prog
            .find_block(block_name)
            .map(|b| {
                let bd = sch.prog.block_data(b);
                let spatial: i64 = bd.spatial_iters().map(|iv| iv.extent).product();
                bd.is_reduction() && spatial < target.num_cores as i64 * self.jobs_per_core
            })
            .unwrap_or(false);
        if !applicable {
            return RuleOutcome::Skip(sch);
        }
        // Fork the space: rfactored + original (rfactor costs an extra pass
        // over the partials; which wins depends on shape).
        match attempt(&sch, |s| self.transform(s, block_name)) {
            Ok(out) => RuleOutcome::Applied(vec![out, sch]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::analysis::program_flops;
    use crate::tir::{rd, sp, AExpr, BinOp, BlockBody, CExpr, DType, Program, Region};

    /// A long dot-product: tiny spatial extent, big reduction.
    fn dot(n: i64) -> Program {
        let mut p = Program::new("dot");
        let a = p.param("A", vec![n], DType::F32);
        let b = p.param("B", vec![n], DType::F32);
        let c = p.param("C", vec![1], DType::F32);
        p.emit("dot", &[sp("u", 1), rd("k", n)], |iv| {
            let (u, k) = (iv[0], iv[1]);
            (
                vec![
                    Region::point(a, vec![AExpr::Var(k)]),
                    Region::point(b, vec![AExpr::Var(k)]),
                ],
                vec![Region::point(c, vec![AExpr::Var(u)])],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(
                        BinOp::Mul,
                        CExpr::load(a, vec![AExpr::Var(k)]),
                        CExpr::load(b, vec![AExpr::Var(k)]),
                    ),
                },
            )
        });
        p
    }

    #[test]
    fn rfactors_long_dot_product() {
        let t = Target::cpu_avx512();
        let prog = dot(1 << 16);
        let flops = program_flops(&prog);
        let m = AddRfactor::new();
        let variants = m.apply(Schedule::new(prog, 1), "dot", &t).into_variants();
        assert_eq!(variants.len(), 2);
        let rf = &variants[0];
        rf.prog.check_integrity().unwrap();
        // A partial-sum block appeared; flops grow only by the final merge.
        assert!(rf.prog.blocks().len() > 1);
        assert!(program_flops(&rf.prog) >= flops);
    }

    #[test]
    fn skips_blocks_with_plenty_of_spatial_parallelism() {
        let t = Target::cpu_avx512();
        let prog = crate::workloads::matmul(1, 128, 128, 128);
        let m = AddRfactor::new();
        let variants = m.apply(Schedule::new(prog, 1), "matmul", &t).into_variants();
        assert_eq!(variants.len(), 1);
        assert!(variants[0].trace.is_empty());
    }
}
