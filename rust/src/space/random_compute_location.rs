//! Random-Compute-Location: the paper's Figure 3 Step 2. Samples where an
//! elementwise block computes — standalone at the root, inlined, or fused
//! under a loop of its consumer (`compute-at`) / producer
//! (`reverse-compute-at`, e.g. ReLU into a Dense tile loop).

use crate::schedule::{LoopRef, SchResult, Schedule};
use crate::sim::Target;
use crate::space::{attempt, RuleOutcome, ScheduleRule};
use crate::tir::BlockBody;

pub struct RandomComputeLocation;

impl RandomComputeLocation {
    pub fn new() -> RandomComputeLocation {
        RandomComputeLocation
    }

    fn transform(&self, s: &mut Schedule, block_name: &str) -> SchResult<()> {
        let b = s.get_block(block_name)?;
        let item = s.block(b)?;
        let has_consumers = !s.prog.consumers_of(item).is_empty();
        if has_consumers {
            // Forward: compute-at handles Root / Inlined sentinels itself.
            let loc = s.sample_compute_location(b)?;
            s.compute_at(b, loc)?;
        } else {
            // Output block: fuse *into the producer's* loop nest. Draw from
            // {root} ∪ candidate loops (inlining an output block into a
            // reduction producer is not legal, so exclude -2).
            let candidates = s.compute_location_candidates(item);
            if candidates.is_empty() {
                return Ok(());
            }
            let pick = s.rng.gen_range(candidates.len() + 1);
            let d = if pick == 0 { -1 } else { (pick - 1) as i64 };
            let loc = s.sample_compute_location_decided(b, Some(d))?;
            if s.loop_ref(loc) != LoopRef::Root {
                s.reverse_compute_at(b, loc)?;
            } else {
                // Recorded no-op keeps root mutations on-support.
                s.reverse_compute_at(b, loc)?;
            }
        }
        Ok(())
    }
}

impl Default for RandomComputeLocation {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleRule for RandomComputeLocation {
    fn name(&self) -> &str {
        "random-compute-location"
    }

    fn describe(&self) -> String {
        "sample where a movable elementwise block computes (root / fused / inlined)".into()
    }

    fn apply(&self, sch: Schedule, block_name: &str, _target: &Target) -> RuleOutcome {
        // Only movable elementwise blocks.
        let movable = sch
            .prog
            .find_block(block_name)
            .map(|b| {
                let bd = sch.prog.block_data(b);
                matches!(bd.body, BlockBody::Assign { .. }) && bd.write_is_trivial()
            })
            .unwrap_or(false);
        if !movable {
            return RuleOutcome::Skip(sch);
        }
        match attempt(&sch, |s| self.transform(s, block_name)) {
            Ok(out) => RuleOutcome::Applied(vec![out]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::sim::Target;
    use crate::trace::FactorArg;
    use crate::workloads;

    /// dense_relu with dense pre-tiled so there are interesting locations.
    fn tiled_dense_relu(seed: u64) -> Schedule {
        let prog = workloads::fused_dense(64, 64, 64);
        let mut s = Schedule::new(prog, seed);
        // Inline bias first so relu directly consumes dense's output.
        let bias = s.get_block("bias_add").unwrap();
        s.compute_inline(bias).unwrap();
        let d = s.get_block("dense").unwrap();
        let loops = s.get_loops(d).unwrap();
        let i = s.split(loops[0], &[FactorArg::Lit(4), FactorArg::Lit(16)]).unwrap();
        let _ = i;
        s
    }

    #[test]
    fn output_block_fuses_under_producer_loop() {
        let t = Target::cpu_avx512();
        let m = RandomComputeLocation::new();
        // Across seeds we must see at least one fused placement (relu's
        // loops_above non-empty under the dense nest) and at least one root.
        let mut fused = 0;
        let mut root = 0;
        for seed in 0..16 {
            let s = tiled_dense_relu(seed);
            let out = m.apply(s, "relu", &t).into_variants().pop().unwrap();
            out.prog.check_integrity().unwrap();
            let relu = out.prog.find_block("relu").unwrap();
            let dense = out.prog.find_block("dense").unwrap();
            let shared = out
                .prog
                .loops_above(relu)
                .iter()
                .any(|l| out.prog.loops_above(dense).contains(l));
            if shared {
                fused += 1;
            } else {
                root += 1;
            }
        }
        assert!(fused > 0, "never fused");
        assert!(root > 0, "never stayed at root");
    }

    #[test]
    fn reduction_block_not_moved() {
        let t = Target::cpu_avx512();
        let m = RandomComputeLocation::new();
        let prog = workloads::matmul(1, 32, 32, 32);
        let s = Schedule::new(prog, 0);
        let out = m.apply(s, "matmul", &t).into_variants().pop().unwrap();
        assert!(out.trace.is_empty());
    }

    #[test]
    fn sampled_location_is_recorded_and_replayable() {
        use crate::trace::replay;
        let t = Target::cpu_avx512();
        let m = RandomComputeLocation::new();
        let s = tiled_dense_relu(3);
        let prog0 = workloads::fused_dense(64, 64, 64);
        let out = m.apply(s, "relu", &t).into_variants().pop().unwrap();
        let r = replay(&out.trace, &prog0, 0).unwrap();
        assert_eq!(
            crate::tir::structural_hash(&out.prog),
            crate::tir::structural_hash(&r.prog)
        );
    }
}
