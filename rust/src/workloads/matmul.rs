//! Matrix-multiply-family workload builders: GMM, TBG, dense, fused-dense.

use crate::tir::{rd, sp, AExpr, BinOp, BlockBody, CExpr, DType, Program, Region, UnOp};

/// Plain matmul `C[m,n] = sum_k A[m,k] * B[k,n]` with a batch dim folded in.
/// A.2 GMM: batch=1, N=M=K=128.
pub fn matmul(b: i64, m: i64, n: i64, k: i64) -> Program {
    let mut p = Program::new("matmul");
    let a = p.param("A", vec![b, m, k], DType::F32);
    let bb = p.param("B", vec![b, k, n], DType::F32);
    let c = p.param("C", vec![b, m, n], DType::F32);
    p.emit(
        "matmul",
        &[sp("b", b), sp("i", m), sp("j", n), rd("k", k)],
        |iv| {
            let (vb, vi, vj, vk) = (iv[0], iv[1], iv[2], iv[3]);
            (
                vec![
                    Region::point(a, vec![AExpr::Var(vb), AExpr::Var(vi), AExpr::Var(vk)]),
                    Region::point(bb, vec![AExpr::Var(vb), AExpr::Var(vk), AExpr::Var(vj)]),
                ],
                vec![Region::point(c, vec![AExpr::Var(vb), AExpr::Var(vi), AExpr::Var(vj)])],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(
                        BinOp::Mul,
                        CExpr::load(a, vec![AExpr::Var(vb), AExpr::Var(vi), AExpr::Var(vk)]),
                        CExpr::load(bb, vec![AExpr::Var(vb), AExpr::Var(vk), AExpr::Var(vj)]),
                    ),
                },
            )
        },
    );
    p
}

/// Transpose + batched matmul: the BERT attention-score pattern.
/// A.2 TBG: batch=1, seq=128, head=12, dim=64. Computes
/// `K_t[h,d,s] = K[s,h,d]` then `S[h,i,j] = sum_d Q[i,h,d] * K_t[h,d,j]`.
pub fn transpose_batch_matmul(seq: i64, head: i64, dim: i64) -> Program {
    let mut p = Program::new("transpose_batch_matmul");
    let q = p.param("Q", vec![seq, head, dim], DType::F32);
    let kbuf = p.param("K", vec![seq, head, dim], DType::F32);
    let kt = p.temp("K_t", vec![head, dim, seq], DType::F32);
    let s = p.param("S", vec![head, seq, seq], DType::F32);
    p.emit(
        "transpose",
        &[sp("h", head), sp("d", dim), sp("s", seq)],
        |iv| {
            let (vh, vd, vs) = (iv[0], iv[1], iv[2]);
            (
                vec![Region::point(kbuf, vec![AExpr::Var(vs), AExpr::Var(vh), AExpr::Var(vd)])],
                vec![Region::point(kt, vec![AExpr::Var(vh), AExpr::Var(vd), AExpr::Var(vs)])],
                BlockBody::Assign {
                    expr: CExpr::load(kbuf, vec![AExpr::Var(vs), AExpr::Var(vh), AExpr::Var(vd)]),
                },
            )
        },
    );
    p.emit(
        "batch_matmul",
        &[sp("h", head), sp("i", seq), sp("j", seq), rd("d", dim)],
        |iv| {
            let (vh, vi, vj, vd) = (iv[0], iv[1], iv[2], iv[3]);
            (
                vec![
                    Region::point(q, vec![AExpr::Var(vi), AExpr::Var(vh), AExpr::Var(vd)]),
                    Region::point(kt, vec![AExpr::Var(vh), AExpr::Var(vd), AExpr::Var(vj)]),
                ],
                vec![Region::point(s, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)])],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(
                        BinOp::Mul,
                        CExpr::load(q, vec![AExpr::Var(vi), AExpr::Var(vh), AExpr::Var(vd)]),
                        CExpr::load(kt, vec![AExpr::Var(vh), AExpr::Var(vd), AExpr::Var(vj)]),
                    ),
                },
            )
        },
    );
    p
}

/// Dense (fully-connected): `Y[i,j] = sum_k X[i,k] * W[j,k]`, row-major
/// weights as in framework `Linear` layers.
pub fn dense(m: i64, n: i64, k: i64) -> Program {
    let mut p = Program::new("dense");
    let x = p.param("X", vec![m, k], DType::F32);
    let w = p.param("W", vec![n, k], DType::F32);
    let y = p.param("Y", vec![m, n], DType::F32);
    p.emit("dense", &[sp("i", m), sp("j", n), rd("k", k)], |iv| {
        let (vi, vj, vk) = (iv[0], iv[1], iv[2]);
        (
            vec![
                Region::point(x, vec![AExpr::Var(vi), AExpr::Var(vk)]),
                Region::point(w, vec![AExpr::Var(vj), AExpr::Var(vk)]),
            ],
            vec![Region::point(y, vec![AExpr::Var(vi), AExpr::Var(vj)])],
            BlockBody::Reduce {
                init: CExpr::ConstF(0.0),
                op: BinOp::Add,
                rhs: CExpr::bin(
                    BinOp::Mul,
                    CExpr::load(x, vec![AExpr::Var(vi), AExpr::Var(vk)]),
                    CExpr::load(w, vec![AExpr::Var(vj), AExpr::Var(vk)]),
                ),
            },
        )
    });
    p
}

/// Dense + bias + ReLU: the `fused-dense` BERT subgraph of Figure 10a.
/// Default Fig. 10a shape: seq=128 rows, 768 -> 3072 (BERT-base FFN).
pub fn fused_dense(m: i64, n: i64, k: i64) -> Program {
    let mut p = dense(m, n, k);
    p.name = "fused_dense".into();
    let y = 2; // dense output
    let bias = p.param("Bias", vec![n], DType::F32);
    let t = p.temp("Biased", vec![m, n], DType::F32);
    let out = p.param("Out", vec![m, n], DType::F32);
    p.emit("bias_add", &[sp("i", m), sp("j", n)], |iv| {
        let (vi, vj) = (iv[0], iv[1]);
        let idx = vec![AExpr::Var(vi), AExpr::Var(vj)];
        (
            vec![
                Region::point(y, idx.clone()),
                Region::point(bias, vec![AExpr::Var(vj)]),
            ],
            vec![Region::point(t, idx.clone())],
            BlockBody::Assign {
                expr: CExpr::bin(
                    BinOp::Add,
                    CExpr::load(y, idx),
                    CExpr::load(bias, vec![AExpr::Var(vj)]),
                ),
            },
        )
    });
    p.emit("relu", &[sp("i", m), sp("j", n)], |iv| {
        let idx = vec![AExpr::Var(iv[0]), AExpr::Var(iv[1])];
        (
            vec![Region::point(t, idx.clone())],
            vec![Region::point(out, idx.clone())],
            BlockBody::Assign {
                expr: CExpr::un(UnOp::Relu, CExpr::load(t, idx)),
            },
        )
    });
    p
}

/// Full scaled-dot-product attention as one fused subgraph:
/// `S = Q K^T; P = softmax(S); O = P V` (QK^T -> softmax -> V). This is
/// the attention workload the fusion pass would otherwise assemble from
/// TBG + SFM + GMM; having it as a single program exercises the
/// multi-reduction fused space directly and backs the dynamic-shape
/// sequence buckets (`att-seq64/128/256`).
pub fn attention(seq: i64, head: i64, dim: i64) -> Program {
    let mut p = Program::new("attention");
    let q = p.param("Q", vec![seq, head, dim], DType::F32);
    let kbuf = p.param("K", vec![seq, head, dim], DType::F32);
    let v = p.param("V", vec![seq, head, dim], DType::F32);
    let s = p.temp("S", vec![head, seq, seq], DType::F32);
    let mx = p.temp("Max", vec![head, seq], DType::F32);
    let ex = p.temp("Exp", vec![head, seq, seq], DType::F32);
    let sm = p.temp("Sum", vec![head, seq], DType::F32);
    let pr = p.temp("P", vec![head, seq, seq], DType::F32);
    let out = p.param("O", vec![seq, head, dim], DType::F32);
    p.emit(
        "scores",
        &[sp("h", head), sp("i", seq), sp("j", seq), rd("d", dim)],
        |iv| {
            let (vh, vi, vj, vd) = (iv[0], iv[1], iv[2], iv[3]);
            (
                vec![
                    Region::point(q, vec![AExpr::Var(vi), AExpr::Var(vh), AExpr::Var(vd)]),
                    Region::point(kbuf, vec![AExpr::Var(vj), AExpr::Var(vh), AExpr::Var(vd)]),
                ],
                vec![Region::point(s, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)])],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(
                        BinOp::Mul,
                        CExpr::load(q, vec![AExpr::Var(vi), AExpr::Var(vh), AExpr::Var(vd)]),
                        CExpr::load(kbuf, vec![AExpr::Var(vj), AExpr::Var(vh), AExpr::Var(vd)]),
                    ),
                },
            )
        },
    );
    p.emit("row_max", &[sp("h", head), sp("i", seq), rd("j", seq)], |iv| {
        let (vh, vi, vj) = (iv[0], iv[1], iv[2]);
        (
            vec![Region::point(s, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)])],
            vec![Region::point(mx, vec![AExpr::Var(vh), AExpr::Var(vi)])],
            BlockBody::Reduce {
                init: CExpr::ConstF(f64::NEG_INFINITY),
                op: BinOp::Max,
                rhs: CExpr::load(s, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)]),
            },
        )
    });
    p.emit("exp", &[sp("h", head), sp("i", seq), sp("j", seq)], |iv| {
        let (vh, vi, vj) = (iv[0], iv[1], iv[2]);
        (
            vec![
                Region::point(s, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)]),
                Region::point(mx, vec![AExpr::Var(vh), AExpr::Var(vi)]),
            ],
            vec![Region::point(ex, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)])],
            BlockBody::Assign {
                expr: CExpr::un(
                    UnOp::Exp,
                    CExpr::bin(
                        BinOp::Sub,
                        CExpr::load(s, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)]),
                        CExpr::load(mx, vec![AExpr::Var(vh), AExpr::Var(vi)]),
                    ),
                ),
            },
        )
    });
    p.emit("row_sum", &[sp("h", head), sp("i", seq), rd("j", seq)], |iv| {
        let (vh, vi, vj) = (iv[0], iv[1], iv[2]);
        (
            vec![Region::point(ex, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)])],
            vec![Region::point(sm, vec![AExpr::Var(vh), AExpr::Var(vi)])],
            BlockBody::Reduce {
                init: CExpr::ConstF(0.0),
                op: BinOp::Add,
                rhs: CExpr::load(ex, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)]),
            },
        )
    });
    p.emit("divide", &[sp("h", head), sp("i", seq), sp("j", seq)], |iv| {
        let (vh, vi, vj) = (iv[0], iv[1], iv[2]);
        (
            vec![
                Region::point(ex, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)]),
                Region::point(sm, vec![AExpr::Var(vh), AExpr::Var(vi)]),
            ],
            vec![Region::point(pr, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)])],
            BlockBody::Assign {
                expr: CExpr::bin(
                    BinOp::Div,
                    CExpr::load(ex, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)]),
                    CExpr::load(sm, vec![AExpr::Var(vh), AExpr::Var(vi)]),
                ),
            },
        )
    });
    p.emit(
        "pv",
        &[sp("i", seq), sp("h", head), sp("d", dim), rd("j", seq)],
        |iv| {
            let (vi, vh, vd, vj) = (iv[0], iv[1], iv[2], iv[3]);
            (
                vec![
                    Region::point(pr, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)]),
                    Region::point(v, vec![AExpr::Var(vj), AExpr::Var(vh), AExpr::Var(vd)]),
                ],
                vec![Region::point(out, vec![AExpr::Var(vi), AExpr::Var(vh), AExpr::Var(vd)])],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(
                        BinOp::Mul,
                        CExpr::load(pr, vec![AExpr::Var(vh), AExpr::Var(vi), AExpr::Var(vj)]),
                        CExpr::load(v, vec![AExpr::Var(vj), AExpr::Var(vh), AExpr::Var(vd)]),
                    ),
                },
            )
        },
    );
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::analysis::program_flops;

    #[test]
    fn gmm_flops() {
        let p = matmul(1, 128, 128, 128);
        p.check_integrity().unwrap();
        assert_eq!(program_flops(&p), 2.0 * 128f64.powi(3));
    }

    #[test]
    fn tbg_structure() {
        let p = transpose_batch_matmul(128, 12, 64);
        p.check_integrity().unwrap();
        let t = p.find_block("transpose").unwrap();
        let bmm = p.find_block("batch_matmul").unwrap();
        assert_eq!(p.consumers_of(t), vec![bmm]);
        // matmul flops dominate: 2 * 12 * 128 * 128 * 64 (transpose is copy-only)
        assert_eq!(program_flops(&p), 2.0 * 12.0 * 128.0 * 128.0 * 64.0);
    }

    #[test]
    fn fused_dense_chain() {
        let p = fused_dense(128, 3072, 768);
        p.check_integrity().unwrap();
        let d = p.find_block("dense").unwrap();
        let b = p.find_block("bias_add").unwrap();
        let r = p.find_block("relu").unwrap();
        assert_eq!(p.consumers_of(d), vec![b]);
        assert_eq!(p.consumers_of(b), vec![r]);
        // bias_add and relu blocks have trivial writes -> inlineable.
        assert!(p.block_data(b).write_is_trivial());
        assert!(p.block_data(r).write_is_trivial());
    }

    #[test]
    fn dense_weight_layout_is_nk() {
        let p = dense(64, 256, 512);
        assert_eq!(p.buffers[1].shape, vec![256, 512]);
    }

    #[test]
    fn attention_dataflow_and_flops() {
        let p = attention(128, 12, 64);
        p.check_integrity().unwrap();
        assert_eq!(p.blocks().len(), 6);
        let sc = p.find_block("scores").unwrap();
        // scores feed both row_max and exp.
        assert_eq!(p.consumers_of(sc).len(), 2);
        // The two matmuls dominate: 2 * 2 * h * s^2 * d plus O(h*s^2) softmax.
        let mm = 2.0 * 2.0 * 12.0 * 128.0 * 128.0 * 64.0;
        let f = program_flops(&p);
        assert!(f > mm && f < mm * 1.1, "{f} vs {mm}");
    }
}
