//! Normalization / softmax / elementwise workload builders.

use crate::tir::{rd, sp, AExpr, BinOp, BlockBody, CExpr, DType, Program, Region, UnOp};

/// Row L2-normalization. A.2 NRM: batch=1, m=256, n=256.
/// `sq[i] = sum_j x[i,j]^2; out[i,j] = x[i,j] * rsqrt(sq[i])`.
pub fn norm(b: i64, m: i64, n: i64) -> Program {
    let _ = b; // batch folded into m (batch=1 in A.2)
    let mut p = Program::new("norm");
    let x = p.param("X", vec![m, n], DType::F32);
    let sq = p.temp("Sq", vec![m], DType::F32);
    let out = p.param("Out", vec![m, n], DType::F32);
    p.emit("sq_sum", &[sp("i", m), rd("j", n)], |iv| {
        let (vi, vj) = (iv[0], iv[1]);
        (
            vec![Region::point(x, vec![AExpr::Var(vi), AExpr::Var(vj)])],
            vec![Region::point(sq, vec![AExpr::Var(vi)])],
            BlockBody::Reduce {
                init: CExpr::ConstF(0.0),
                op: BinOp::Add,
                rhs: CExpr::bin(
                    BinOp::Mul,
                    CExpr::load(x, vec![AExpr::Var(vi), AExpr::Var(vj)]),
                    CExpr::load(x, vec![AExpr::Var(vi), AExpr::Var(vj)]),
                ),
            },
        )
    });
    p.emit("normalize", &[sp("i", m), sp("j", n)], |iv| {
        let (vi, vj) = (iv[0], iv[1]);
        (
            vec![
                Region::point(x, vec![AExpr::Var(vi), AExpr::Var(vj)]),
                Region::point(sq, vec![AExpr::Var(vi)]),
            ],
            vec![Region::point(out, vec![AExpr::Var(vi), AExpr::Var(vj)])],
            BlockBody::Assign {
                expr: CExpr::bin(
                    BinOp::Mul,
                    CExpr::load(x, vec![AExpr::Var(vi), AExpr::Var(vj)]),
                    CExpr::un(UnOp::Rsqrt, CExpr::load(sq, vec![AExpr::Var(vi)])),
                ),
            },
        )
    });
    p
}

/// Row softmax. A.2 SFM: batch=1, m=256, n=256. Four blocks:
/// row-max, exp(x - max), row-sum, divide.
pub fn softmax(b: i64, m: i64, n: i64) -> Program {
    let _ = b;
    let mut p = Program::new("softmax");
    let x = p.param("X", vec![m, n], DType::F32);
    let mx = p.temp("Max", vec![m], DType::F32);
    let ex = p.temp("Exp", vec![m, n], DType::F32);
    let sm = p.temp("Sum", vec![m], DType::F32);
    let out = p.param("Out", vec![m, n], DType::F32);
    p.emit("row_max", &[sp("i", m), rd("j", n)], |iv| {
        let (vi, vj) = (iv[0], iv[1]);
        (
            vec![Region::point(x, vec![AExpr::Var(vi), AExpr::Var(vj)])],
            vec![Region::point(mx, vec![AExpr::Var(vi)])],
            BlockBody::Reduce {
                init: CExpr::ConstF(f64::NEG_INFINITY),
                op: BinOp::Max,
                rhs: CExpr::load(x, vec![AExpr::Var(vi), AExpr::Var(vj)]),
            },
        )
    });
    p.emit("exp", &[sp("i", m), sp("j", n)], |iv| {
        let (vi, vj) = (iv[0], iv[1]);
        (
            vec![
                Region::point(x, vec![AExpr::Var(vi), AExpr::Var(vj)]),
                Region::point(mx, vec![AExpr::Var(vi)]),
            ],
            vec![Region::point(ex, vec![AExpr::Var(vi), AExpr::Var(vj)])],
            BlockBody::Assign {
                expr: CExpr::un(
                    UnOp::Exp,
                    CExpr::bin(
                        BinOp::Sub,
                        CExpr::load(x, vec![AExpr::Var(vi), AExpr::Var(vj)]),
                        CExpr::load(mx, vec![AExpr::Var(vi)]),
                    ),
                ),
            },
        )
    });
    p.emit("row_sum", &[sp("i", m), rd("j", n)], |iv| {
        let (vi, vj) = (iv[0], iv[1]);
        (
            vec![Region::point(ex, vec![AExpr::Var(vi), AExpr::Var(vj)])],
            vec![Region::point(sm, vec![AExpr::Var(vi)])],
            BlockBody::Reduce {
                init: CExpr::ConstF(0.0),
                op: BinOp::Add,
                rhs: CExpr::load(ex, vec![AExpr::Var(vi), AExpr::Var(vj)]),
            },
        )
    });
    p.emit("divide", &[sp("i", m), sp("j", n)], |iv| {
        let (vi, vj) = (iv[0], iv[1]);
        (
            vec![
                Region::point(ex, vec![AExpr::Var(vi), AExpr::Var(vj)]),
                Region::point(sm, vec![AExpr::Var(vi)]),
            ],
            vec![Region::point(out, vec![AExpr::Var(vi), AExpr::Var(vj)])],
            BlockBody::Assign {
                expr: CExpr::bin(
                    BinOp::Div,
                    CExpr::load(ex, vec![AExpr::Var(vi), AExpr::Var(vj)]),
                    CExpr::load(sm, vec![AExpr::Var(vi)]),
                ),
            },
        )
    });
    p
}

/// Elementwise ReLU over a flat buffer (the paper's Figure 2 example).
pub fn relu(numel: i64) -> Program {
    let mut p = Program::new("relu");
    let a = p.param("A", vec![numel], DType::F32);
    let b = p.param("B", vec![numel], DType::F32);
    p.emit("relu", &[sp("i", numel)], |iv| {
        let i = iv[0];
        (
            vec![Region::point(a, vec![AExpr::Var(i)])],
            vec![Region::point(b, vec![AExpr::Var(i)])],
            BlockBody::Assign {
                expr: CExpr::un(UnOp::Relu, CExpr::load(a, vec![AExpr::Var(i)])),
            },
        )
    });
    p
}

/// Elementwise add of two equal-shaped 2-D tensors (residual connections).
pub fn add2d(m: i64, n: i64) -> Program {
    let mut p = Program::new("add2d");
    let a = p.param("A", vec![m, n], DType::F32);
    let b = p.param("B", vec![m, n], DType::F32);
    let c = p.param("C", vec![m, n], DType::F32);
    p.emit("add", &[sp("i", m), sp("j", n)], |iv| {
        let idx = vec![AExpr::Var(iv[0]), AExpr::Var(iv[1])];
        (
            vec![Region::point(a, idx.clone()), Region::point(b, idx.clone())],
            vec![Region::point(c, idx.clone())],
            BlockBody::Assign {
                expr: CExpr::bin(
                    BinOp::Add,
                    CExpr::load(a, idx.clone()),
                    CExpr::load(b, idx),
                ),
            },
        )
    });
    p
}

/// Elementwise add of two NCHW feature maps (CNN residual connections;
/// shape-compatible with `conv2d` output so the fusion pass can bind
/// them).
pub fn add4d(c: i64, h: i64) -> Program {
    let mut p = Program::new("add4d");
    let shape = vec![1, c, h, h];
    let a = p.param("A", shape.clone(), DType::F32);
    let b = p.param("B", shape.clone(), DType::F32);
    let out = p.param("C", shape, DType::F32);
    p.emit(
        "add",
        &[sp("n", 1), sp("c", c), sp("y", h), sp("x", h)],
        |iv| {
            let idx: Vec<AExpr> = iv.iter().map(|&v| AExpr::Var(v)).collect();
            (
                vec![Region::point(a, idx.clone()), Region::point(b, idx.clone())],
                vec![Region::point(out, idx.clone())],
                BlockBody::Assign {
                    expr: CExpr::bin(
                        BinOp::Add,
                        CExpr::load(a, idx.clone()),
                        CExpr::load(b, idx),
                    ),
                },
            )
        },
    );
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::analysis::program_flops;

    #[test]
    fn norm_two_blocks() {
        let p = norm(1, 256, 256);
        p.check_integrity().unwrap();
        assert_eq!(p.blocks().len(), 2);
        let sq = p.find_block("sq_sum").unwrap();
        let nm = p.find_block("normalize").unwrap();
        assert_eq!(p.consumers_of(sq), vec![nm]);
    }

    #[test]
    fn softmax_four_block_chain() {
        let p = softmax(1, 256, 256);
        p.check_integrity().unwrap();
        assert_eq!(p.blocks().len(), 4);
        let exp = p.find_block("exp").unwrap();
        // exp feeds both row_sum and divide.
        assert_eq!(p.consumers_of(exp).len(), 2);
    }

    #[test]
    fn relu_flops_is_numel() {
        let p = relu(1024);
        assert_eq!(program_flops(&p), 1024.0);
    }

    #[test]
    fn add2d_reads_two_buffers() {
        let p = add2d(16, 16);
        let b = p.find_block("add").unwrap();
        assert_eq!(p.block_data(b).reads.len(), 2);
        assert_eq!(program_flops(&p), 256.0);
    }

    #[test]
    fn add4d_matches_conv_output_shape() {
        let p = add4d(64, 56);
        p.check_integrity().unwrap();
        assert_eq!(p.buffers[0].shape, vec![1, 64, 56, 56]);
        assert_eq!(program_flops(&p), 64.0 * 56.0 * 56.0);
    }
}
