//! The Appendix A.2 workload suite + parameterized operator builders.
//!
//! Each workload is an initial program `e_0` built with the exact
//! configurations the paper tabulates (Appendix A.2). Parameterized
//! builders in [`conv`], [`matmul`] and [`elementwise`] are reused by the
//! [`graph`](crate::graph) model zoo at other shapes.

pub mod conv;
pub mod elementwise;
pub mod matmul;

pub use conv::{conv1d, conv2d, conv2d_bn_relu, conv3d, conv_out, depthwise_conv2d, transposed_conv2d, Conv2dParams};
pub use elementwise::{add2d, add4d, norm, relu, softmax};
pub use matmul::{attention, dense, fused_dense, matmul, transpose_batch_matmul};

use crate::tir::Program;

/// A named workload from the paper's evaluation suite.
#[derive(Clone)]
pub struct Workload {
    /// Short name used in Figure 8 ("C1D", "GMM", ...).
    pub name: &'static str,
    /// Human description with the A.2 configuration.
    pub description: &'static str,
    /// Build the initial program `e_0`.
    pub build: fn() -> Program,
}

impl std::fmt::Debug for Workload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Workload").field("name", &self.name).finish()
    }
}

fn build_c1d() -> Program {
    conv1d(1, 256, 64, 128, 3, 2, 1)
}
fn build_c2d() -> Program {
    conv2d(Conv2dParams::new(1, 224, 224, 3, 64, 7, 2, 3))
}
fn build_c3d() -> Program {
    conv3d(1, 16, 224, 224, 3, 64, 7, 2, 3)
}
fn build_dep() -> Program {
    depthwise_conv2d(1, 112, 112, 32, 3, 1, 1)
}
fn build_dil() -> Program {
    let mut p = Conv2dParams::new(1, 224, 224, 3, 64, 7, 2, 3);
    p.dilation = 2;
    let mut prog = conv2d(p);
    prog.name = "dilated_conv2d".into();
    prog
}
fn build_gmm() -> Program {
    matmul(1, 128, 128, 128)
}
fn build_grp() -> Program {
    let mut p = Conv2dParams::new(1, 56, 56, 64, 128, 3, 2, 1);
    p.groups = 4;
    conv2d(p)
}
fn build_t2d() -> Program {
    transposed_conv2d(1, 4, 4, 512, 256, 4, 2, 1)
}
fn build_cbr() -> Program {
    conv2d_bn_relu(Conv2dParams::new(1, 224, 224, 3, 64, 7, 2, 3))
}
fn build_tbg() -> Program {
    transpose_batch_matmul(128, 12, 64)
}
fn build_nrm() -> Program {
    norm(1, 256, 256)
}
fn build_sfm() -> Program {
    softmax(1, 256, 256)
}
fn build_fused_dense() -> Program {
    fused_dense(128, 3072, 768)
}
fn build_att_seq64() -> Program {
    attention(64, 12, 64)
}
fn build_att_seq128() -> Program {
    attention(128, 12, 64)
}
fn build_att_seq256() -> Program {
    attention(256, 12, 64)
}

/// The 12 operator/subgraph workloads of Figure 8, in paper order.
pub fn suite() -> Vec<Workload> {
    vec![
        Workload { name: "C1D", description: "1D conv: b1 l256 ci64 co128 k3 s2 p1", build: build_c1d },
        Workload { name: "C2D", description: "2D conv: b1 224x224 ci3 co64 k7 s2 p3", build: build_c2d },
        Workload { name: "C3D", description: "3D conv: b1 d16 224x224 ci3 co64 k7 s2 p3", build: build_c3d },
        Workload { name: "DEP", description: "depthwise conv: b1 112x112 c32 k3 s1 p1", build: build_dep },
        Workload { name: "DIL", description: "dilated conv: b1 224x224 ci3 co64 k7 s2 p3 d2", build: build_dil },
        Workload { name: "GMM", description: "matmul: b1 n=m=k=128", build: build_gmm },
        Workload { name: "GRP", description: "group conv: b1 56x56 ci64 co128 k3 s2 p1 g4", build: build_grp },
        Workload { name: "T2D", description: "transposed conv: b1 4x4 ci512 co256 k4 s2 p1", build: build_t2d },
        Workload { name: "CBR", description: "conv+bn+relu: b1 224x224 ci3 co64 k7 s2 p3", build: build_cbr },
        Workload { name: "TBG", description: "transpose+batch-matmul: b1 s128 h12 d64", build: build_tbg },
        Workload { name: "NRM", description: "norm: b1 256x256", build: build_nrm },
        Workload { name: "SFM", description: "softmax: b1 256x256", build: build_sfm },
    ]
}

/// The Figure 10a `fused-dense` BERT subgraph.
pub fn fused_dense_workload() -> Workload {
    Workload {
        name: "fused-dense",
        description: "dense+bias+relu: 128x768 -> 3072 (BERT FFN)",
        build: build_fused_dense,
    }
}

/// Named workloads beyond the 12-entry Figure 8 suite: the Figure 10a
/// fused-dense subgraph, and the attention subgraph (QK^T -> softmax -> V,
/// BERT-base heads) with its dynamic-shape sequence-length buckets — a
/// dynamic-seq model tunes each bucket once and dispatches at runtime.
pub fn extras() -> Vec<Workload> {
    vec![
        fused_dense_workload(),
        Workload { name: "ATT", description: "attention: QK^T+softmax+V, s128 h12 d64", build: build_att_seq128 },
        Workload { name: "ATT-seq64", description: "attention bucket: s64 h12 d64", build: build_att_seq64 },
        Workload { name: "ATT-seq128", description: "attention bucket: s128 h12 d64", build: build_att_seq128 },
        Workload { name: "ATT-seq256", description: "attention bucket: s256 h12 d64", build: build_att_seq256 },
    ]
}

/// Every addressable workload: the suite plus [`extras`].
pub fn all() -> Vec<Workload> {
    let mut v = suite();
    v.extend(extras());
    v
}

/// Canonical form for name lookups: case-insensitive, `_` == `-`. Shared
/// by [`by_name`] and [`crate::graph::graph_by_name`] so the two
/// namespaces resolve identically (and can be checked for collisions).
pub fn canon_name(name: &str) -> String {
    name.to_lowercase().replace('_', "-")
}

/// Look any workload (suite or extra) up by canonicalized name.
pub fn by_name(name: &str) -> Option<Workload> {
    let c = canon_name(name);
    all().into_iter().find(|w| canon_name(w.name) == c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::analysis::program_flops;

    #[test]
    fn suite_has_twelve_buildable_workloads() {
        let s = suite();
        assert_eq!(s.len(), 12);
        for w in &s {
            let p = (w.build)();
            p.check_integrity().unwrap();
            assert!(program_flops(&p) > 0.0, "{} has zero flops", w.name);
            assert!(!p.blocks().is_empty());
        }
    }

    #[test]
    fn names_unique() {
        let s = suite();
        let mut names: Vec<_> = s.iter().map(|w| w.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 12);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("gmm").is_some());
        assert!(by_name("GMM").is_some());
        assert!(by_name("fused-dense").is_some());
        assert!(by_name("FUSED_DENSE").is_some());
        assert!(by_name("att").is_some());
        assert!(by_name("att_seq256").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn extras_build_and_buckets_scale() {
        for w in extras() {
            let p = (w.build)();
            p.check_integrity().unwrap();
            assert!(program_flops(&p) > 0.0, "{}", w.name);
        }
        // Bucketed attention FLOPs grow with sequence length.
        let f = |n: &str| program_flops(&(by_name(n).unwrap().build)());
        assert!(f("ATT-seq64") < f("ATT-seq128"));
        assert!(f("ATT-seq128") < f("ATT-seq256"));
        assert_eq!(f("ATT"), f("ATT-seq128"));
    }

    #[test]
    fn workload_and_model_namespaces_are_disjoint() {
        // One resolver convention across both namespaces: every workload
        // name must stay distinct from every model-zoo name under the
        // shared canonicalization, so a CLI name is never ambiguous.
        for m in crate::graph::MODEL_NAMES {
            assert!(by_name(m).is_none(), "{m} is both a model and a workload");
            assert!(crate::graph::graph_by_name(m).is_some(), "{m}");
        }
        for w in all() {
            assert!(
                crate::graph::graph_by_name(w.name).is_none(),
                "{} is both a workload and a model",
                w.name
            );
            // Each resolves under case / separator variants.
            assert!(by_name(&w.name.to_uppercase()).is_some());
            assert!(by_name(&canon_name(w.name).replace('-', "_")).is_some());
        }
    }

    #[test]
    fn simulator_accepts_all_workloads() {
        use crate::sim::{simulate, Target};
        let cpu = Target::cpu_avx512();
        for w in suite() {
            let p = (w.build)();
            let r = simulate(&p, &cpu).unwrap();
            assert!(r.total_s > 0.0, "{} zero latency", w.name);
        }
    }
}
