//! Convolution workload builders (Appendix A.2).
//!
//! Padding is folded into the input buffer shape — the conv block reads a
//! pre-padded buffer at `oh*stride + kh*dilation` — exactly the structure
//! TVM produces after inlining the pad stage. This preserves the flop count
//! and the memory-footprint structure that drive the simulator while
//! keeping every index affine.

use crate::tir::{rd, sp, AExpr, BinOp, BlockBody, CExpr, DType, Program, Region};

/// Output spatial extent of a conv dim.
pub fn conv_out(size: i64, kernel: i64, stride: i64, pad: i64, dilation: i64) -> i64 {
    (size + 2 * pad - dilation * (kernel - 1) - 1) / stride + 1
}

/// Parameters of a 2-D convolution workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Conv2dParams {
    pub n: i64,
    pub h: i64,
    pub w: i64,
    pub ci: i64,
    pub co: i64,
    pub k: i64,
    pub stride: i64,
    pub pad: i64,
    pub dilation: i64,
    pub groups: i64,
}

impl Conv2dParams {
    pub fn new(n: i64, h: i64, w: i64, ci: i64, co: i64, k: i64, stride: i64, pad: i64) -> Self {
        Conv2dParams { n, h, w, ci, co, k, stride, pad, dilation: 1, groups: 1 }
    }

    pub fn oh(&self) -> i64 {
        conv_out(self.h, self.k, self.stride, self.pad, self.dilation)
    }

    pub fn ow(&self) -> i64 {
        conv_out(self.w, self.k, self.stride, self.pad, self.dilation)
    }
}

/// 1-D convolution. A.2 C1D: batch=1, length=256, ci=64, co=128, k=3, s=2, p=1.
pub fn conv1d(n: i64, l: i64, ci: i64, co: i64, k: i64, stride: i64, pad: i64) -> Program {
    let ol = conv_out(l, k, stride, pad, 1);
    let lp = l + 2 * pad;
    let mut p = Program::new("conv1d");
    let x = p.param("X", vec![n, ci, lp], DType::F32);
    let w = p.param("W", vec![co, ci, k], DType::F32);
    let y = p.param("Y", vec![n, co, ol], DType::F32);
    p.emit(
        "conv1d",
        &[sp("n", n), sp("co", co), sp("ol", ol), rd("ci", ci), rd("k", k)],
        |iv| {
            let (vn, vco, vol, vci, vk) = (iv[0], iv[1], iv[2], iv[3], iv[4]);
            let ix = AExpr::Var(vol).mul(stride).add(AExpr::Var(vk));
            (
                vec![
                    Region::point(x, vec![AExpr::Var(vn), AExpr::Var(vci), ix.clone()]),
                    Region::point(w, vec![AExpr::Var(vco), AExpr::Var(vci), AExpr::Var(vk)]),
                ],
                vec![Region::point(y, vec![AExpr::Var(vn), AExpr::Var(vco), AExpr::Var(vol)])],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(
                        BinOp::Mul,
                        CExpr::load(x, vec![AExpr::Var(vn), AExpr::Var(vci), ix]),
                        CExpr::load(w, vec![AExpr::Var(vco), AExpr::Var(vci), AExpr::Var(vk)]),
                    ),
                },
            )
        },
    );
    p
}

/// 2-D convolution (optionally grouped / dilated) as a single reduction block.
pub fn conv2d(params: Conv2dParams) -> Program {
    let Conv2dParams { n, h, w: wd, ci, co, k, stride, pad, dilation, groups } = params;
    assert!(ci % groups == 0 && co % groups == 0, "groups must divide channels");
    let (oh, ow) = (params.oh(), params.ow());
    let (hp, wp) = (h + 2 * pad, wd + 2 * pad);
    let cig = ci / groups; // input channels per group
    let cog = co / groups; // output channels per group
    let mut p = Program::new(if groups > 1 { "group_conv2d" } else { "conv2d" });
    let x = p.param("X", vec![n, ci, hp, wp], DType::F32);
    let w = p.param("W", vec![co, cig, k, k], DType::F32);
    let y = p.param("Y", vec![n, co, oh, ow], DType::F32);
    // Iterate (g, cog) instead of co so the group offset stays affine.
    p.emit(
        "conv2d",
        &[
            sp("n", n),
            sp("g", groups),
            sp("cog", cog),
            sp("oh", oh),
            sp("ow", ow),
            rd("cig", cig),
            rd("kh", k),
            rd("kw", k),
        ],
        |iv| {
            let (vn, vg, vcog, voh, vow, vcig, vkh, vkw) =
                (iv[0], iv[1], iv[2], iv[3], iv[4], iv[5], iv[6], iv[7]);
            let co_idx = AExpr::Var(vg).mul(cog).add(AExpr::Var(vcog));
            let ci_idx = AExpr::Var(vg).mul(cig).add(AExpr::Var(vcig));
            let ih = AExpr::Var(voh).mul(stride).add(AExpr::Var(vkh).mul(dilation));
            let iw = AExpr::Var(vow).mul(stride).add(AExpr::Var(vkw).mul(dilation));
            let x_idx = vec![AExpr::Var(vn), ci_idx, ih, iw];
            let w_idx = vec![co_idx.clone(), AExpr::Var(vcig), AExpr::Var(vkh), AExpr::Var(vkw)];
            (
                vec![
                    Region::point(x, x_idx.clone()),
                    Region::point(w, w_idx.clone()),
                ],
                vec![Region::point(
                    y,
                    vec![AExpr::Var(vn), co_idx, AExpr::Var(voh), AExpr::Var(vow)],
                )],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(
                        BinOp::Mul,
                        CExpr::load(x, x_idx),
                        CExpr::load(w, w_idx),
                    ),
                },
            )
        },
    );
    p
}

/// 3-D convolution. A.2 C3D: batch=1, d=16, h=w=224, ci=3, co=64, k=7, s=2, p=3.
#[allow(clippy::too_many_arguments)]
pub fn conv3d(n: i64, d: i64, h: i64, w: i64, ci: i64, co: i64, k: i64, stride: i64, pad: i64) -> Program {
    let od = conv_out(d, k, stride, pad, 1);
    let oh = conv_out(h, k, stride, pad, 1);
    let ow = conv_out(w, k, stride, pad, 1);
    let (dp, hp, wp) = (d + 2 * pad, h + 2 * pad, w + 2 * pad);
    let mut p = Program::new("conv3d");
    let x = p.param("X", vec![n, ci, dp, hp, wp], DType::F32);
    let wt = p.param("W", vec![co, ci, k, k, k], DType::F32);
    let y = p.param("Y", vec![n, co, od, oh, ow], DType::F32);
    p.emit(
        "conv3d",
        &[
            sp("n", n),
            sp("co", co),
            sp("od", od),
            sp("oh", oh),
            sp("ow", ow),
            rd("ci", ci),
            rd("kd", k),
            rd("kh", k),
            rd("kw", k),
        ],
        |iv| {
            let (vn, vco, vod, voh, vow, vci, vkd, vkh, vkw) =
                (iv[0], iv[1], iv[2], iv[3], iv[4], iv[5], iv[6], iv[7], iv[8]);
            let id = AExpr::Var(vod).mul(stride).add(AExpr::Var(vkd));
            let ih = AExpr::Var(voh).mul(stride).add(AExpr::Var(vkh));
            let iw = AExpr::Var(vow).mul(stride).add(AExpr::Var(vkw));
            let x_idx = vec![AExpr::Var(vn), AExpr::Var(vci), id, ih, iw];
            let w_idx = vec![
                AExpr::Var(vco),
                AExpr::Var(vci),
                AExpr::Var(vkd),
                AExpr::Var(vkh),
                AExpr::Var(vkw),
            ];
            (
                vec![Region::point(x, x_idx.clone()), Region::point(wt, w_idx.clone())],
                vec![Region::point(
                    y,
                    vec![AExpr::Var(vn), AExpr::Var(vco), AExpr::Var(vod), AExpr::Var(voh), AExpr::Var(vow)],
                )],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(BinOp::Mul, CExpr::load(x, x_idx), CExpr::load(wt, w_idx)),
                },
            )
        },
    );
    p
}

/// Depthwise 2-D convolution. A.2 DEP: batch=1, h=w=112, c=32, k=3, s=1, p=1.
pub fn depthwise_conv2d(n: i64, h: i64, w: i64, c: i64, k: i64, stride: i64, pad: i64) -> Program {
    let oh = conv_out(h, k, stride, pad, 1);
    let ow = conv_out(w, k, stride, pad, 1);
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    let mut p = Program::new("depthwise_conv2d");
    let x = p.param("X", vec![n, c, hp, wp], DType::F32);
    let wt = p.param("W", vec![c, k, k], DType::F32);
    let y = p.param("Y", vec![n, c, oh, ow], DType::F32);
    p.emit(
        "dwconv2d",
        &[sp("n", n), sp("c", c), sp("oh", oh), sp("ow", ow), rd("kh", k), rd("kw", k)],
        |iv| {
            let (vn, vc, voh, vow, vkh, vkw) = (iv[0], iv[1], iv[2], iv[3], iv[4], iv[5]);
            let ih = AExpr::Var(voh).mul(stride).add(AExpr::Var(vkh));
            let iw = AExpr::Var(vow).mul(stride).add(AExpr::Var(vkw));
            let x_idx = vec![AExpr::Var(vn), AExpr::Var(vc), ih, iw];
            let w_idx = vec![AExpr::Var(vc), AExpr::Var(vkh), AExpr::Var(vkw)];
            (
                vec![Region::point(x, x_idx.clone()), Region::point(wt, w_idx.clone())],
                vec![Region::point(
                    y,
                    vec![AExpr::Var(vn), AExpr::Var(vc), AExpr::Var(voh), AExpr::Var(vow)],
                )],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(BinOp::Mul, CExpr::load(x, x_idx), CExpr::load(wt, w_idx)),
                },
            )
        },
    );
    p
}

/// Transposed 2-D convolution. A.2 T2D: batch=1, h=w=4, ci=512, co=256, k=4,
/// s=2, p=1. Expressed as a dense convolution over the stride-dilated,
/// padded input (shape `(h-1)*s + 1 + 2*(k-1-p)`), which is the standard
/// lowering and preserves the flop/footprint structure.
pub fn transposed_conv2d(n: i64, h: i64, w: i64, ci: i64, co: i64, k: i64, stride: i64, pad: i64) -> Program {
    let hd = (h - 1) * stride + 1 + 2 * (k - 1 - pad); // dilated+padded input extent
    let wd = (w - 1) * stride + 1 + 2 * (k - 1 - pad);
    let oh = hd - k + 1;
    let ow = wd - k + 1;
    let mut p = Program::new("transposed_conv2d");
    let x = p.param("X", vec![n, ci, hd, wd], DType::F32);
    let wt = p.param("W", vec![ci, co, k, k], DType::F32);
    let y = p.param("Y", vec![n, co, oh, ow], DType::F32);
    p.emit(
        "t2d",
        &[sp("n", n), sp("co", co), sp("oh", oh), sp("ow", ow), rd("ci", ci), rd("kh", k), rd("kw", k)],
        |iv| {
            let (vn, vco, voh, vow, vci, vkh, vkw) =
                (iv[0], iv[1], iv[2], iv[3], iv[4], iv[5], iv[6]);
            let ih = AExpr::Var(voh).add(AExpr::Var(vkh));
            let iw = AExpr::Var(vow).add(AExpr::Var(vkw));
            let x_idx = vec![AExpr::Var(vn), AExpr::Var(vci), ih, iw];
            let w_idx = vec![AExpr::Var(vci), AExpr::Var(vco), AExpr::Var(vkh), AExpr::Var(vkw)];
            (
                vec![Region::point(x, x_idx.clone()), Region::point(wt, w_idx.clone())],
                vec![Region::point(
                    y,
                    vec![AExpr::Var(vn), AExpr::Var(vco), AExpr::Var(voh), AExpr::Var(vow)],
                )],
                BlockBody::Reduce {
                    init: CExpr::ConstF(0.0),
                    op: BinOp::Add,
                    rhs: CExpr::bin(BinOp::Mul, CExpr::load(x, x_idx), CExpr::load(wt, w_idx)),
                },
            )
        },
    );
    p
}

/// Conv2d + BatchNorm (folded scale/shift) + ReLU. A.2 CBR.
pub fn conv2d_bn_relu(params: Conv2dParams) -> Program {
    let mut p = conv2d(params);
    p.name = "conv2d_bn_relu".into();
    let (n, co, oh, ow) = (params.n, params.co, params.oh(), params.ow());
    let y = 2; // conv output buffer (X=0, W=1, Y=2)
    // Inference-time batchnorm folds to per-channel scale+shift.
    let scale = p.param("Scale", vec![co], DType::F32);
    let shift = p.param("Shift", vec![co], DType::F32);
    let t = p.temp("BN", vec![n, co, oh, ow], DType::F32);
    let out = p.param("Out", vec![n, co, oh, ow], DType::F32);
    use crate::tir::UnOp;
    p.emit("bn", &[sp("n", n), sp("c", co), sp("h", oh), sp("w", ow)], |iv| {
        let idx = vec![AExpr::Var(iv[0]), AExpr::Var(iv[1]), AExpr::Var(iv[2]), AExpr::Var(iv[3])];
        (
            vec![
                Region::point(y, idx.clone()),
                Region::point(scale, vec![AExpr::Var(iv[1])]),
                Region::point(shift, vec![AExpr::Var(iv[1])]),
            ],
            vec![Region::point(t, idx.clone())],
            BlockBody::Assign {
                expr: CExpr::bin(
                    BinOp::Add,
                    CExpr::bin(
                        BinOp::Mul,
                        CExpr::load(y, idx),
                        CExpr::load(scale, vec![AExpr::Var(iv[1])]),
                    ),
                    CExpr::load(shift, vec![AExpr::Var(iv[1])]),
                ),
            },
        )
    });
    p.emit("relu", &[sp("n", n), sp("c", co), sp("h", oh), sp("w", ow)], |iv| {
        let idx = vec![AExpr::Var(iv[0]), AExpr::Var(iv[1]), AExpr::Var(iv[2]), AExpr::Var(iv[3])];
        (
            vec![Region::point(t, idx.clone())],
            vec![Region::point(out, idx.clone())],
            BlockBody::Assign {
                expr: CExpr::un(UnOp::Relu, CExpr::load(t, idx)),
            },
        )
    });
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::analysis::program_flops;

    #[test]
    fn conv_out_formula() {
        assert_eq!(conv_out(224, 7, 2, 3, 1), 112);
        assert_eq!(conv_out(256, 3, 2, 1, 1), 128);
        assert_eq!(conv_out(224, 7, 2, 3, 2), 109); // dilated
        assert_eq!(conv_out(112, 3, 1, 1, 1), 112);
    }

    #[test]
    fn c2d_flops_match_formula() {
        // A.2 C2D: 1x3x224x224 -> 64, k=7, s=2, p=3 => oh=ow=112
        let p = conv2d(Conv2dParams::new(1, 224, 224, 3, 64, 7, 2, 3));
        p.check_integrity().unwrap();
        let expect = 2.0 * 64.0 * 112.0 * 112.0 * 3.0 * 49.0;
        assert_eq!(program_flops(&p), expect);
    }

    #[test]
    fn grouped_conv_reduces_flops() {
        let dense = conv2d(Conv2dParams::new(1, 56, 56, 64, 128, 3, 2, 1));
        let mut gp = Conv2dParams::new(1, 56, 56, 64, 128, 3, 2, 1);
        gp.groups = 4;
        let grouped = conv2d(gp);
        grouped.check_integrity().unwrap();
        assert_eq!(program_flops(&grouped) * 4.0, program_flops(&dense));
    }

    #[test]
    fn dilated_conv_shape() {
        let mut params = Conv2dParams::new(1, 224, 224, 3, 64, 7, 2, 3);
        params.dilation = 2;
        let p = conv2d(params);
        p.check_integrity().unwrap();
        assert_eq!(params.oh(), 109);
    }

    #[test]
    fn depthwise_flops() {
        let p = depthwise_conv2d(1, 112, 112, 32, 3, 1, 1);
        p.check_integrity().unwrap();
        assert_eq!(program_flops(&p), 2.0 * 32.0 * 112.0 * 112.0 * 9.0);
    }

    #[test]
    fn t2d_output_shape() {
        // 4x4 -> 8x8 with k=4, s=2, p=1
        let p = transposed_conv2d(1, 4, 4, 512, 256, 4, 2, 1);
        p.check_integrity().unwrap();
        // Output buffer Y is params[2]
        assert_eq!(p.buffers[p.params[2]].shape, vec![1, 256, 8, 8]);
    }

    #[test]
    fn cbr_has_three_blocks_chained() {
        let p = conv2d_bn_relu(Conv2dParams::new(1, 224, 224, 3, 64, 7, 2, 3));
        p.check_integrity().unwrap();
        let blocks = p.blocks();
        assert_eq!(blocks.len(), 3);
        let conv = p.find_block("conv2d").unwrap();
        let bn = p.find_block("bn").unwrap();
        let relu = p.find_block("relu").unwrap();
        assert_eq!(p.consumers_of(conv), vec![bn]);
        assert_eq!(p.consumers_of(bn), vec![relu]);
    }

    #[test]
    fn conv1d_flops() {
        let p = conv1d(1, 256, 64, 128, 3, 2, 1);
        p.check_integrity().unwrap();
        assert_eq!(program_flops(&p), 2.0 * 128.0 * 128.0 * 64.0 * 3.0);
    }

    #[test]
    fn conv3d_integrity() {
        let p = conv3d(1, 16, 224, 224, 3, 64, 7, 2, 3);
        p.check_integrity().unwrap();
        assert_eq!(p.blocks().len(), 1);
    }
}
