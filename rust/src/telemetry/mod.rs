//! Observability for the tuning system: metrics + trace spans, on std
//! only.
//!
//! Two halves, both observation-only (recording never takes a decision
//! path, so determinism and byte-identical db output hold with telemetry
//! on or off):
//!
//! - [`metrics`] — named atomic [`Counter`]/[`Gauge`]/[`Histogram`]
//!   instruments in a [`Metrics`] registry that renders Prometheus text
//!   exposition. The process-global registry ([`global`]) backs
//!   `GET /metrics` on the serving front; per-context registries back
//!   `--explain-space` diagnostics.
//! - [`trace_event`] — [`Span`]/[`TraceSink`] emitting Chrome
//!   trace-event JSON through a bounded-queue writer thread, surfaced as
//!   `tune --profile out.json` (open in Perfetto).
//!
//! Metric families and the trace-event schema are documented in
//! `docs/OBSERVABILITY.md`.

pub mod metrics;
pub mod trace_event;

pub use metrics::{
    global, parse_exposition, sanitize_name, valid_name, Counter, Gauge, Histogram, Metrics,
    HISTOGRAM_BUCKETS,
};
pub use trace_event::{maybe_span, validate_trace, Span, TraceSink};
