//! Zero-dep metrics: atomic [`Counter`]/[`Gauge`]/[`Histogram`]
//! instruments plus the [`Metrics`] registry that names them and renders
//! Prometheus text exposition (format 0.0.4).
//!
//! The record path is lock-free: callers register once (a short mutex
//! hold on the registry's name map), cache the returned `Arc` handle,
//! and every `inc`/`observe` after that is a relaxed atomic op — cheap
//! enough to live inside the serving read path and the innermost search
//! loop. Telemetry is observation-only by construction: instruments hold
//! no RNG, take no locks on record, and nothing in the system ever reads
//! a metric to make a decision, so the determinism contract
//! (`(seed, 1 thread) == (seed, N threads)`, byte-identical db output)
//! holds with telemetry on or off.
//!
//! Two registries exist in practice:
//!
//! - the process-global one ([`global`]) — cumulative families scraped
//!   by `GET /metrics` on the serving front (serve, db, search);
//! - per-[`crate::ctx::TuneContext`] instances backing the
//!   `--explain-space` diagnostics, where tests assert *exact* counts
//!   and cross-context bleed would break them.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if n > 0 {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A value that can go up and down (e.g. inflight tune-on-miss requests).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge { v: AtomicI64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, delta: i64) {
        self.v.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Bucket count of every [`Histogram`]: fixed log-scale bounds
/// `1, 2, 4, ..., 2^26`, plus a final overflow (`+Inf`) bucket. With
/// microsecond samples that spans 1µs to ~67s, which covers everything
/// from a snapshot probe to a full tune-on-miss.
pub const HISTOGRAM_BUCKETS: usize = 28;

/// Fixed log₂-bucket histogram over non-negative integer samples (the
/// unit — typically microseconds — is the metric's documented contract,
/// not the type's). The record path is one relaxed increment plus one
/// relaxed add: no locks, no floats, no allocation.
#[derive(Debug)]
pub struct Histogram {
    /// `buckets[i]` counts samples with `value <= 2^i` that no smaller
    /// bucket claimed; the last bucket is the overflow (`+Inf`).
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
    sum: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }

    /// Upper bound of bucket `i`; `None` for the final `+Inf` bucket.
    pub fn bound(i: usize) -> Option<u64> {
        (i + 1 < HISTOGRAM_BUCKETS).then(|| 1u64 << i)
    }

    /// Index of the bucket that owns `v`: the smallest `i` with
    /// `v <= 2^i`, clamped into the overflow bucket. Branch-light — the
    /// innermost search loop and the serving read path call this.
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        if v <= 1 {
            0
        } else {
            (64 - (v - 1).leading_zeros() as usize).min(HISTOGRAM_BUCKETS - 1)
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Sum of all recorded samples.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket counts (not cumulative), in bound order.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Upper-bound estimate of the `q`-quantile (0.0..=1.0): the bound of
    /// the first bucket whose cumulative count reaches `q * count`,
    /// `u64::MAX` when that bucket is the overflow one, 0 when empty.
    /// Within a factor of 2 of the true quantile by construction — the
    /// resolution the log-scale buckets buy.
    pub fn quantile(&self, q: f64) -> u64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::bound(i).unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }
}

/// One registered instrument.
enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Entry {
    help: String,
    instrument: Instrument,
}

/// A named registry of instruments. Registration (get-or-create by name)
/// takes a short mutex; the returned `Arc` handles record lock-free.
/// Names must already be valid Prometheus metric names — see
/// [`sanitize_name`] for turning rule/postproc labels into one.
pub struct Metrics {
    entries: Mutex<BTreeMap<String, Entry>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { entries: Mutex::new(BTreeMap::new()) }
    }

    /// Get-or-register a counter. Panics if `name` is already registered
    /// as a different instrument kind — that is a programming error, not
    /// a runtime condition.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        debug_assert!(valid_name(name), "invalid metric name {name:?}");
        let mut entries = self.entries.lock().unwrap();
        let e = entries.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            instrument: Instrument::Counter(Arc::new(Counter::new())),
        });
        match &e.instrument {
            Instrument::Counter(c) => Arc::clone(c),
            _ => panic!("metric {name} already registered as a non-counter"),
        }
    }

    /// Get-or-register a counter under a name that is guaranteed unique
    /// in this registry: on collision, `_2`, `_3`, ... is appended.
    /// Returns the fresh counter (never a shared one) — per-instance
    /// diagnostics (two rules with the same name in one space) must not
    /// silently share counts.
    pub fn counter_unique(&self, name: &str, help: &str) -> Arc<Counter> {
        let mut entries = self.entries.lock().unwrap();
        let mut unique = name.to_string();
        let mut i = 2;
        while entries.contains_key(&unique) {
            unique = format!("{name}_{i}");
            i += 1;
        }
        debug_assert!(valid_name(&unique), "invalid metric name {unique:?}");
        let c = Arc::new(Counter::new());
        entries.insert(unique, Entry {
            help: help.to_string(),
            instrument: Instrument::Counter(Arc::clone(&c)),
        });
        c
    }

    /// Get-or-register a gauge (kind-mismatch panics, as for counters).
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        debug_assert!(valid_name(name), "invalid metric name {name:?}");
        let mut entries = self.entries.lock().unwrap();
        let e = entries.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            instrument: Instrument::Gauge(Arc::new(Gauge::new())),
        });
        match &e.instrument {
            Instrument::Gauge(g) => Arc::clone(g),
            _ => panic!("metric {name} already registered as a non-gauge"),
        }
    }

    /// Get-or-register a histogram (kind-mismatch panics, as for counters).
    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        debug_assert!(valid_name(name), "invalid metric name {name:?}");
        let mut entries = self.entries.lock().unwrap();
        let e = entries.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            instrument: Instrument::Histogram(Arc::new(Histogram::new())),
        });
        match &e.instrument {
            Instrument::Histogram(h) => Arc::clone(h),
            _ => panic!("metric {name} already registered as a non-histogram"),
        }
    }

    /// Current value of a registered counter, for tests and summaries.
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        let entries = self.entries.lock().unwrap();
        match entries.get(name).map(|e| &e.instrument) {
            Some(Instrument::Counter(c)) => Some(c.get()),
            _ => None,
        }
    }

    /// Registered metric names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.entries.lock().unwrap().keys().cloned().collect()
    }

    /// Render the whole registry in Prometheus text exposition format
    /// 0.0.4: `# HELP` / `# TYPE` per family, cumulative `_bucket{le=..}`
    /// series plus `_sum`/`_count` for histograms. Families render in
    /// name order (BTreeMap), so output is stable for a fixed state.
    pub fn render(&self) -> String {
        let entries = self.entries.lock().unwrap();
        let mut out = String::new();
        for (name, e) in entries.iter() {
            if !e.help.is_empty() {
                out.push_str(&format!("# HELP {name} {}\n", e.help.replace('\n', " ")));
            }
            match &e.instrument {
                Instrument::Counter(c) => {
                    out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()));
                }
                Instrument::Gauge(g) => {
                    out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
                }
                Instrument::Histogram(h) => {
                    out.push_str(&format!("# TYPE {name} histogram\n"));
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, c) in counts.iter().enumerate() {
                        cum += c;
                        match Histogram::bound(i) {
                            Some(b) => {
                                out.push_str(&format!("{name}_bucket{{le=\"{b}\"}} {cum}\n"));
                            }
                            None => {
                                out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                            }
                        }
                    }
                    out.push_str(&format!("{name}_sum {}\n", h.sum()));
                    out.push_str(&format!("{name}_count {}\n", cum));
                }
            }
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

/// The process-global registry: cumulative serve/db/search families, the
/// body of `GET /metrics`. Per-context diagnostics live in their own
/// [`Metrics`] instances instead (exact counts per tuning context).
pub fn global() -> &'static Arc<Metrics> {
    static GLOBAL: OnceLock<Arc<Metrics>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(Metrics::new()))
}

/// Whether `name` is a valid Prometheus metric name
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`).
pub fn valid_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Turn an arbitrary label (rule name like `auto-inline`) into a valid
/// metric-name fragment: ASCII alphanumerics pass, everything else maps
/// to `_`, and a leading digit gains a `_` prefix.
pub fn sanitize_name(label: &str) -> String {
    let mut out = String::with_capacity(label.len() + 1);
    for c in label.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    if out.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(true) {
        out.insert(0, '_');
    }
    out
}

/// Parse Prometheus text exposition into `sample name (with label block
/// verbatim) -> value`, validating the grammar line by line: comment
/// lines must be `# HELP <name> ...` or `# TYPE <name> <type>`, sample
/// lines must be `<name>[{labels}] <value>`. This is what the `/metrics`
/// tests and the CI smoke job check responses against.
pub fn parse_exposition(text: &str) -> Result<BTreeMap<String, f64>, String> {
    let mut out = BTreeMap::new();
    for (no, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            let (kw, tail) = rest.split_once(' ').ok_or(format!("line {}: bare comment {line:?}", no + 1))?;
            if kw != "HELP" && kw != "TYPE" {
                return Err(format!("line {}: unknown comment keyword {kw:?}", no + 1));
            }
            let name = tail.split_whitespace().next().unwrap_or("");
            if !valid_name(name) {
                return Err(format!("line {}: invalid metric name {name:?}", no + 1));
            }
            if kw == "TYPE" {
                let ty = tail.split_whitespace().nth(1).unwrap_or("");
                if !matches!(ty, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                    return Err(format!("line {}: invalid metric type {ty:?}", no + 1));
                }
            }
            continue;
        }
        // Sample line: name, optional {label} block, then the value.
        let (series, value) = match line.find('{') {
            Some(b) => {
                let close = line[b..]
                    .find('}')
                    .map(|i| b + i)
                    .ok_or(format!("line {}: unterminated label block", no + 1))?;
                (&line[..=close], line[close + 1..].trim())
            }
            None => {
                let sp = line
                    .find(char::is_whitespace)
                    .ok_or(format!("line {}: sample without value {line:?}", no + 1))?;
                (&line[..sp], line[sp..].trim())
            }
        };
        let bare = series.split('{').next().unwrap_or("");
        if !valid_name(bare) {
            return Err(format!("line {}: invalid sample name {bare:?}", no + 1));
        }
        let v: f64 = value
            .parse()
            .map_err(|_| format!("line {}: unparseable value {value:?}", no + 1))?;
        out.insert(series.to_string(), v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let m = Metrics::new();
        let c = m.counter("requests_total", "requests");
        c.inc();
        c.add(4);
        c.add(0);
        assert_eq!(c.get(), 5);
        // Re-registration hands back the same instrument.
        assert_eq!(m.counter("requests_total", "requests").get(), 5);
        assert_eq!(m.counter_value("requests_total"), Some(5));
        let g = m.gauge("inflight", "inflight");
        g.add(3);
        g.add(-1);
        assert_eq!(g.get(), 2);
        g.set(-7);
        assert_eq!(g.get(), -7);
    }

    #[test]
    fn counter_unique_never_shares() {
        let m = Metrics::new();
        let a = m.counter_unique("rule_x_applied_total", "");
        let b = m.counter_unique("rule_x_applied_total", "");
        a.inc();
        assert_eq!(a.get(), 1);
        assert_eq!(b.get(), 0, "colliding registration must not share a counter");
        assert!(m.names().contains(&"rule_x_applied_total_2".to_string()));
    }

    #[test]
    fn histogram_buckets_own_their_ranges() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(5), 3);
        assert_eq!(Histogram::bucket_index(u64::MAX), HISTOGRAM_BUCKETS - 1);
        let h = Histogram::new();
        h.observe(3);
        h.observe(100);
        h.observe(u64::MAX / 2);
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 3 + 100 + u64::MAX / 2);
        assert_eq!(h.quantile(0.0), 4, "smallest sample's bucket bound");
        assert_eq!(h.quantile(1.0), u64::MAX, "overflow bucket quantile");
    }

    #[test]
    fn render_is_valid_exposition_and_parses_back() {
        let m = Metrics::new();
        m.counter("serve_hits_total", "lookup hits").add(7);
        m.gauge("serve_inflight_tunes", "inflight").set(1);
        let h = m.histogram("serve_request_micros", "request latency");
        h.observe(5);
        h.observe(900);
        let text = m.render();
        assert!(text.contains("# TYPE serve_hits_total counter"));
        assert!(text.contains("serve_hits_total 7"));
        assert!(text.contains("# TYPE serve_request_micros histogram"));
        assert!(text.contains("serve_request_micros_bucket{le=\"+Inf\"} 2"));
        let parsed = parse_exposition(&text).expect("rendered exposition must parse");
        assert_eq!(parsed.get("serve_hits_total"), Some(&7.0));
        assert_eq!(parsed.get("serve_request_micros_count"), Some(&2.0));
        assert_eq!(parsed.get("serve_request_micros_sum"), Some(&905.0));
        // Cumulative buckets are monotone and end at the count.
        let inf = parsed.get("serve_request_micros_bucket{le=\"+Inf\"}").copied();
        assert_eq!(inf, Some(2.0));
    }

    #[test]
    fn parse_rejects_malformed_exposition() {
        assert!(parse_exposition("# BOGUS x y\n").is_err());
        assert!(parse_exposition("# TYPE x flavor\n").is_err());
        assert!(parse_exposition("9bad_name 1\n").is_err());
        assert!(parse_exposition("name_without_value\n").is_err());
        assert!(parse_exposition("name not-a-number\n").is_err());
        assert!(parse_exposition("name{le=\"1\" 3\n").is_err(), "unterminated label block");
        assert!(parse_exposition("").unwrap().is_empty());
    }

    #[test]
    fn sanitize_produces_valid_fragments() {
        assert_eq!(sanitize_name("auto-inline"), "auto_inline");
        assert_eq!(sanitize_name("use-tensor-core/mxu"), "use_tensor_core_mxu");
        assert_eq!(sanitize_name("2fast"), "_2fast");
        assert!(valid_name(&sanitize_name("")));
        assert!(valid_name(&format!("space_rule_{}_applied_total", sanitize_name("verify-integrity"))));
    }
}
