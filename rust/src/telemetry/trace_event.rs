//! Structured trace spans in Chrome trace-event format.
//!
//! A [`TraceSink`] owns a background writer thread fed through a
//! [`BoundedQueue`] (the same backpressure channel the measurement
//! pipeline uses): instrumented threads serialize one JSON event and push
//! it; the writer drains the queue into a `[...]`-array JSONL file that
//! Perfetto (<https://ui.perfetto.dev>) and `chrome://tracing` open
//! directly. `tune --profile out.json` wires a sink into the tuning
//! context so a whole run — warm-start, transfer seeding, every round's
//! evolve/measure/commit — shows up on a timeline.
//!
//! Observation-only, like the metrics registry: spans read the clock and
//! push strings, and nothing in the search ever reads them back, so
//! profiles do not perturb results. Timestamps are microseconds since the
//! sink was created (Chrome trace `ts` is relative anyway), and thread
//! lanes use small per-process ordinals handed out on first use rather
//! than unstable OS thread ids.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::search::parallel::BoundedQueue;
use crate::util::json::Json;

/// Queue depth between instrumented threads and the writer. Deep enough
/// that the writer's I/O never stalls a search round; bounded so a stuck
/// disk applies backpressure instead of growing without limit.
const TRACE_QUEUE_CAPACITY: usize = 4096;

/// Small per-process thread ordinal for the trace `tid` field (OS thread
/// ids have no stable integer form on std). First thread to emit gets
/// lane 1, and so on; lanes are stable for a thread's lifetime.
fn trace_tid() -> u64 {
    static NEXT_TID: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// A sink for trace events backed by a file and a writer thread.
/// Cheap to share (`Arc`); emitting is one JSON serialization plus a
/// queue push. Call [`Self::finish`] to close the array and flush —
/// a finished file is strict JSON, an abandoned one is still loadable
/// by Perfetto (it tolerates a missing `]`).
pub struct TraceSink {
    queue: Arc<BoundedQueue<String>>,
    epoch: Instant,
    events: AtomicU64,
    dropped: AtomicU64,
    writer: Mutex<Option<JoinHandle<std::io::Result<u64>>>>,
}

impl TraceSink {
    /// Create a sink writing to `path` (truncates). The writer thread
    /// starts immediately.
    pub fn to_file(path: &Path) -> std::io::Result<Arc<TraceSink>> {
        let file = File::create(path)?;
        let queue = Arc::new(BoundedQueue::new(TRACE_QUEUE_CAPACITY));
        let writer_queue = Arc::clone(&queue);
        let handle = std::thread::spawn(move || write_loop(file, &writer_queue));
        Ok(Arc::new(TraceSink {
            queue,
            epoch: Instant::now(),
            events: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            writer: Mutex::new(Some(handle)),
        }))
    }

    /// Microseconds since the sink was created (the trace timebase).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Begin a duration span; the span emits one complete (`ph:"X"`)
    /// event when dropped.
    pub fn span(self: &Arc<Self>, name: impl Into<String>, cat: &'static str) -> Span {
        Span {
            sink: Some(Arc::clone(self)),
            name: name.into(),
            cat,
            args: Vec::new(),
            start_us: self.now_us(),
        }
    }

    /// Emit an instant (`ph:"i"`) event with optional numeric arguments —
    /// e.g. the search's time-to-quality points
    /// (`trials`, `best_latency_s`, `wall_ms`).
    pub fn instant(&self, name: &str, cat: &str, args: &[(&str, f64)]) {
        let mut fields = vec![
            ("name", Json::str(name)),
            ("cat", Json::str(cat)),
            ("ph", Json::str("i")),
            ("s", Json::str("t")),
            ("ts", Json::num(self.now_us() as f64)),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(trace_tid() as f64)),
        ];
        if !args.is_empty() {
            fields.push((
                "args",
                Json::obj(args.iter().map(|(k, v)| (*k, Json::num(*v))).collect()),
            ));
        }
        self.emit(Json::obj(fields).to_string());
    }

    /// Emit a complete (`ph:"X"`) event covering `[start_us, start_us + dur_us]`.
    pub fn complete(&self, name: &str, cat: &str, start_us: u64, dur_us: u64, args: &[(String, f64)]) {
        let mut fields = vec![
            ("name", Json::str(name)),
            ("cat", Json::str(cat)),
            ("ph", Json::str("X")),
            ("ts", Json::num(start_us as f64)),
            ("dur", Json::num(dur_us as f64)),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(trace_tid() as f64)),
        ];
        if !args.is_empty() {
            fields.push((
                "args",
                Json::obj(args.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect()),
            ));
        }
        self.emit(Json::obj(fields).to_string());
    }

    fn emit(&self, line: String) {
        if self.queue.push(line) {
            self.events.fetch_add(1, Ordering::Relaxed);
        } else {
            // Queue closed (finish() already ran): count, don't block.
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Events accepted so far.
    pub fn events(&self) -> u64 {
        self.events.load(Ordering::Relaxed)
    }

    /// Events dropped because they arrived after [`Self::finish`].
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Close the event stream, wait for the writer to flush, and return
    /// how many events were written. Idempotent: later calls return
    /// `Ok(0)` without touching the file.
    pub fn finish(&self) -> std::io::Result<u64> {
        self.queue.close();
        let handle = self.writer.lock().unwrap().take();
        match handle {
            Some(h) => h.join().unwrap_or_else(|_| {
                Err(std::io::Error::other("trace writer thread panicked"))
            }),
            None => Ok(0),
        }
    }
}

impl Drop for TraceSink {
    fn drop(&mut self) {
        // Best-effort flush if the caller never called finish().
        self.queue.close();
        if let Some(h) = self.writer.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// The writer thread: open a JSON array, stream events separated by
/// `,\n` (separator *before* each subsequent event, so an interrupted
/// file has no trailing comma), close the array on drain.
fn write_loop(file: File, queue: &BoundedQueue<String>) -> std::io::Result<u64> {
    let mut w = BufWriter::new(file);
    w.write_all(b"[\n")?;
    let mut written = 0u64;
    while let Some(line) = queue.pop() {
        if written > 0 {
            w.write_all(b",\n")?;
        }
        w.write_all(line.as_bytes())?;
        written += 1;
    }
    w.write_all(b"\n]\n")?;
    w.flush()?;
    Ok(written)
}

/// An in-flight duration span. Dropping it emits the complete event; a
/// disabled span (no sink — profiling off) is two `Option` checks and
/// otherwise free. Attach numeric arguments with [`Self::arg`].
pub struct Span {
    sink: Option<Arc<TraceSink>>,
    name: String,
    cat: &'static str,
    args: Vec<(String, f64)>,
    start_us: u64,
}

impl Span {
    /// A disabled span: records nothing, emits nothing.
    pub fn disabled() -> Span {
        Span {
            sink: None,
            name: String::new(),
            cat: "",
            args: Vec::new(),
            start_us: 0,
        }
    }

    /// Whether this span will emit an event.
    pub fn is_enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Attach a numeric argument shown in the Perfetto detail pane.
    /// No-op on a disabled span.
    pub fn arg(&mut self, key: &str, value: f64) {
        if self.sink.is_some() {
            self.args.push((key.to_string(), value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(sink) = self.sink.take() {
            let end = sink.now_us();
            let dur = end.saturating_sub(self.start_us);
            sink.complete(&self.name, self.cat, self.start_us, dur, &self.args);
        }
    }
}

/// Span on an optional sink: the uniform call site for code that may or
/// may not be running under `--profile`.
pub fn maybe_span(sink: Option<&Arc<TraceSink>>, name: impl Into<String>, cat: &'static str) -> Span {
    match sink {
        Some(s) => s.span(name, cat),
        None => Span::disabled(),
    }
}

/// Validate a trace file's contents: must parse as a JSON array of event
/// objects each carrying the required Chrome trace-event keys. Returns
/// the event count. Used by the `profile` subcommand and the CI smoke.
pub fn validate_trace(text: &str) -> Result<usize, String> {
    let v = Json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    let events = v.as_arr().ok_or("top-level value is not an array")?;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str())
            .ok_or(format!("event {i}: missing \"ph\""))?;
        if ev.get("name").and_then(|n| n.as_str()).is_none() {
            return Err(format!("event {i}: missing \"name\""));
        }
        if ev.get("ts").and_then(|t| t.as_f64()).is_none() {
            return Err(format!("event {i}: missing numeric \"ts\""));
        }
        if ph == "X" && ev.get("dur").and_then(|d| d.as_f64()).is_none() {
            return Err(format!("event {i}: complete event missing \"dur\""));
        }
        for key in ["pid", "tid"] {
            if ev.get(key).and_then(|x| x.as_f64()).is_none() {
                return Err(format!("event {i}: missing numeric {key:?}"));
            }
        }
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("metaschedule-trace-{}-{name}.json", std::process::id()));
        p
    }

    #[test]
    fn sink_writes_a_valid_trace_array() {
        let path = tmp_path("basic");
        let sink = TraceSink::to_file(&path).unwrap();
        {
            let mut sp = sink.span("round 0", "search");
            sp.arg("trials", 64.0);
        }
        sink.instant("best-improved", "search", &[("trials", 64.0), ("best_latency_s", 0.5)]);
        let _empty = sink.span("evolve", "search");
        drop(_empty);
        let written = sink.finish().unwrap();
        assert_eq!(written, 3);
        assert_eq!(sink.events(), 3);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_trace(&text).unwrap(), 3);
        // Spot-check the schema Perfetto relies on.
        let v = Json::parse(&text).unwrap();
        let events = v.as_arr().unwrap();
        assert_eq!(events[0].get("ph").and_then(|p| p.as_str()), Some("X"));
        assert_eq!(events[1].get("ph").and_then(|p| p.as_str()), Some("i"));
        assert!(events[0].get("dur").and_then(|d| d.as_f64()).is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn finish_is_idempotent_and_late_events_drop() {
        let path = tmp_path("idempotent");
        let sink = TraceSink::to_file(&path).unwrap();
        sink.instant("one", "t", &[]);
        assert_eq!(sink.finish().unwrap(), 1);
        sink.instant("late", "t", &[]);
        assert_eq!(sink.dropped(), 1);
        assert_eq!(sink.finish().unwrap(), 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_trace(&text).unwrap(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn disabled_spans_are_free_and_silent() {
        let mut sp = Span::disabled();
        assert!(!sp.is_enabled());
        sp.arg("ignored", 1.0);
        drop(sp);
        let sp2 = maybe_span(None, "x", "y");
        assert!(!sp2.is_enabled());
    }

    #[test]
    fn concurrent_emitters_interleave_safely() {
        let path = tmp_path("concurrent");
        let sink = TraceSink::to_file(&path).unwrap();
        std::thread::scope(|s| {
            for t in 0..4 {
                let sink = Arc::clone(&sink);
                s.spawn(move || {
                    for i in 0..50 {
                        sink.instant(&format!("t{t}-{i}"), "stress", &[("i", i as f64)]);
                    }
                });
            }
        });
        assert_eq!(sink.finish().unwrap(), 200);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_trace(&text).unwrap(), 200);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validate_rejects_malformed_traces() {
        assert!(validate_trace("not json").is_err());
        assert!(validate_trace("{}").is_err(), "object is not an event array");
        assert!(validate_trace("[{\"name\":\"x\"}]").is_err(), "missing ph/ts");
        assert!(
            validate_trace("[{\"name\":\"x\",\"ph\":\"X\",\"ts\":0,\"pid\":1,\"tid\":1}]").is_err(),
            "complete event needs dur"
        );
        assert_eq!(validate_trace("[]").unwrap(), 0);
    }
}
