//! `metaschedule` CLI — the Layer-3 entrypoint.
//!
//! ```text
//! metaschedule list                              # workloads + models
//! metaschedule tune --workload GMM [--target cpu] [--trials 64] [--threads N]
//! metaschedule tune-model --model bert-base [--target cpu] [--trials 32]
//! metaschedule exp <fig8|fig9|fig10a|fig10b|table1|all> [--target cpu]
//!                  [--trials N] [--seed S] [--threads N] [--out results.jsonl]
//! metaschedule pjrt-verify                       # artifact correctness gate
//!
//! `--threads` caps the OS threads of the search pipeline (0 = all
//! cores); it never changes tuning results, only wall-clock.
//! ```

use metaschedule::exp::{self, ExpConfig};
use metaschedule::graph;
use metaschedule::sim::Target;
use metaschedule::tir::{print_program, PrintOptions};
use metaschedule::util::cli::Args;
use metaschedule::workloads;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cmd = args.positional.first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "list" => list(),
        "tune" => tune(&args),
        "tune-model" => tune_model(&args),
        "exp" => experiment(&args),
        "pjrt-verify" => pjrt_verify(&args),
        _ => {
            eprintln!(
                "usage: metaschedule <list|tune|tune-model|exp|pjrt-verify> [flags]\n\
                 see rust/src/main.rs header for details"
            );
            std::process::exit(2);
        }
    }
}

fn cfg_of(args: &Args) -> ExpConfig {
    ExpConfig {
        trials: args.flag_usize("trials", 64),
        seed: args.flag_u64("seed", 42),
        threads: args.flag_usize("threads", 0),
    }
}

fn target_of(args: &Args) -> Target {
    let name = args.flag_or("target", "cpu");
    Target::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown target {name} (cpu|gpu|tpu)");
        std::process::exit(2);
    })
}

fn list() {
    println!("operator workloads (Appendix A.2):");
    for w in workloads::suite() {
        println!("  {:<4} {}", w.name, w.description);
    }
    println!(
        "  {:<4} {}",
        "fused-dense",
        workloads::fused_dense_workload().description
    );
    println!("end-to-end models:");
    for m in graph::MODEL_NAMES {
        let tasks = graph::extract_tasks(&graph::by_name(m).unwrap());
        println!("  {:<14} {} unique tasks", m, tasks.len());
    }
}

fn tune(args: &Args) {
    let name = args.flag_or("workload", "GMM");
    let Some(w) = workloads::by_name(&name) else {
        eprintln!("unknown workload {name}; see `metaschedule list`");
        std::process::exit(2);
    };
    let target = target_of(args);
    let cfg = cfg_of(args);
    let prog = (w.build)();
    println!("== tuning {} on {} ({} trials)", w.name, target.name, cfg.trials);
    let naive = metaschedule::sim::simulate(&prog, &target)
        .map(|r| r.total_s)
        .unwrap_or(f64::NAN);
    let r = exp::tune_metaschedule(&prog, &target, &cfg);
    println!(
        "naive {:.2} us -> tuned {:.2} us ({:.1}x) in {} trials",
        naive * 1e6,
        r.best_latency_s * 1e6,
        naive / r.best_latency_s,
        r.trials
    );
    if args.has_switch("show-program") {
        println!("{}", print_program(&r.best_prog, PrintOptions::default()));
    }
    if args.has_switch("show-trace") {
        println!("{}", metaschedule::trace::serde::trace_to_text(&r.best_trace));
    }
}

fn tune_model(args: &Args) {
    let name = args.flag_or("model", "bert-base");
    let target = target_of(args);
    let cfg = cfg_of(args);
    let Some(ops) = graph::by_name(&name) else {
        eprintln!("unknown model {name}; see `metaschedule list`");
        std::process::exit(2);
    };
    println!("== tuning {name} on {} ({} trials/task)", target.name, cfg.trials);
    let vendor = graph::vendor_e2e(&ops, &target);
    let ms = exp::fig9::metaschedule_e2e(&name, &target, &cfg);
    println!(
        "vendor (PyTorch-class) e2e {:.3} ms; MetaSchedule e2e {:.3} ms ({:.2}x)",
        vendor * 1e3,
        ms * 1e3,
        vendor / ms
    );
}

fn experiment(args: &Args) {
    let which = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".into());
    let cfg = cfg_of(args);
    let out = args.flag("out").map(|s| s.to_string());
    let mut reports = Vec::new();
    match which.as_str() {
        "fig8" => {
            reports.push(exp::fig8::run(&Target::cpu_avx512(), &cfg, None));
            reports.push(exp::fig8::run(&Target::gpu(), &cfg, None));
        }
        "fig9" => {
            reports.push(exp::fig9::run(&Target::cpu_avx512(), &cfg, None));
            reports.push(exp::fig9::run(&Target::gpu(), &cfg, None));
        }
        "fig10a" => reports.push(exp::fig10::run_10a(&cfg)),
        "fig10b" => reports.push(exp::fig10::run_10b(&cfg)),
        "table1" => reports.push(exp::table1::run(&Target::cpu_avx512(), &cfg, None)),
        "all" => {
            reports.push(exp::fig8::run(&Target::cpu_avx512(), &cfg, None));
            reports.push(exp::fig8::run(&Target::gpu(), &cfg, None));
            reports.push(exp::fig9::run(&Target::cpu_avx512(), &cfg, None));
            reports.push(exp::fig9::run(&Target::gpu(), &cfg, None));
            reports.push(exp::fig10::run_10a(&cfg));
            reports.push(exp::fig10::run_10b(&cfg));
            reports.push(exp::table1::run(&Target::cpu_avx512(), &cfg, None));
        }
        other => {
            eprintln!("unknown experiment {other}");
            std::process::exit(2);
        }
    }
    for r in &reports {
        r.print();
        if let Some(path) = &out {
            if let Err(e) = r.write(path) {
                eprintln!("failed writing {path}: {e}");
            }
        }
    }
}

fn pjrt_verify(args: &Args) {
    let dir = args.flag_or("artifacts", "artifacts");
    let variants = metaschedule::runtime::scan_variants(std::path::Path::new(&dir));
    if variants.is_empty() {
        eprintln!("no artifacts under {dir}; run `make artifacts` first");
        std::process::exit(1);
    }
    let mut runner = match metaschedule::runtime::PjrtRunner::new(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("cannot start PJRT runtime: {e}");
            std::process::exit(1);
        }
    };
    println!("platform: {}", runner.platform());
    for v in &variants {
        let err = runner.verify_gmm(*v, 128, 128, 128).expect("execution");
        let status = if err < 1e-3 { "OK" } else { "FAIL" };
        println!("  {:<30} max|err| = {err:.2e}  {status}", v.artifact_name());
        assert!(err < 1e-3, "artifact {v:?} numerics diverge");
    }
    println!("all {} variants verified against host matmul", variants.len());
}
