//! `metaschedule` CLI — the Layer-3 entrypoint.
//!
//! ```text
//! metaschedule list                              # workloads + models
//! metaschedule tune --workload GMM [--target cpu] [--trials 64] [--threads N] [--db t.jsonl]
//!                  [--rules default] [--mutators default] [--postprocs default] [--explain-space]
//!                  [--no-feature-cache]            # extract features fresh (byte-identical results)
//!                  [--transfer-from cpu [--transfer-db donor.jsonl]] [--no-transfer]
//!                  [--profile trace.json]          # Chrome-trace spans of the tune (Perfetto)
//! metaschedule tune-model --model bert-base [--target cpu] [--trials 32] [--db t.jsonl]
//!                  [--fused]                      # tune the graph-fused task set (fewer, larger tasks)
//!                  [--alloc round-robin|greedy|gradient]  # task-scheduler budget allocation policy
//!                  [--objective mse|rank]         # cost-model training objective
//! metaschedule exp <fig8|fig9|fig10a|fig10b|table1|all> [--target cpu]
//!                  [--trials N] [--seed S] [--threads N] [--out results.jsonl] [--db t.jsonl]
//! metaschedule db stats --db t.jsonl             # tuning-database summary (file or sharded dir)
//! metaschedule db top --workload GMM -k 5 --db t.jsonl
//! metaschedule db compact --db t.jsonl [-k 32] [--repair] [--threads N]  # GC: top-k + failures
//!                  [--stale-rules <label|names|#digest|->]  # also drop a retired rule set's records
//! metaschedule db migrate --db t.jsonl --out db-dir [--shards 8]  # single file -> sharded dir
//! metaschedule db transfer-candidates --db t.jsonl --workload GMM --target gpu [--from cpu]
//! metaschedule serve GMM SFM --db t.jsonl [--target cpu] [--miss-trials 16]  # 0 = read-only
//!                  [--watch [--poll-ms 500]]   # read-only; re-serve when the db file changes
//! metaschedule serve --listen 127.0.0.1:8080 --db db-dir [--workers 4] [--max-pending 64]
//!                  [--max-inflight 1]          # zero-dep HTTP/1.1 front; GET /shutdown to stop
//!                  [--access-log log.jsonl]    # structured access log; GET /metrics = Prometheus
//! metaschedule profile trace.json                # validate + summarize a --profile output
//! metaschedule pjrt-verify                       # artifact correctness gate
//!
//! Every command accepts `--verbosity error|warn|info|debug` (or the
//! `RUST_PALLAS_LOG` env var) to gate stderr diagnostics; stdout result
//! lines are never gated.
//!
//! `--threads` caps the OS threads of the search pipeline (0 = all
//! cores); it never changes tuning results, only wall-clock.
//!
//! `--db` points tuning at a persistent record database: a `.jsonl`
//! path is the classic single file, a directory is the sharded layout
//! (`MANIFEST.json` + `shard-NN.jsonl`, see docs/DB_FORMAT.md); either
//! way runs warm-start from it, commit every measurement back to it,
//! and are therefore resumable across sessions (see README "Tuning
//! database").
//!
//! `serve` is the read path: it builds an indexed in-memory snapshot of
//! the db (no JSONL replay per lookup), reports hit/miss + the replayed
//! best latency per named workload, and falls back to a bounded
//! tune-on-miss (`--miss-trials 0` = report-only) that commits back to
//! the db (see README "Serving tuned programs"). `--watch` keeps the
//! process alive and re-serves whenever the db file's (len, mtime,
//! content fingerprint) signature changes — refresh on change, not on a
//! timer.
//!
//! `--transfer-from <target>` injects the named target's records for the
//! same workload as cross-target priors: the best compatible donors are
//! re-measured on the destination target (never trusted as-is) and the
//! cost model pretrains on their features with a mismatch discount (see
//! README "Cross-target transfer"). Donors come from `--db`, or from a
//! separate read-only `--transfer-db` archive; `--no-transfer` disables
//! everything and byte-reproduces the cold-start run.
//!
//! `--rules`/`--mutators`/`--postprocs` compose the search space from
//! the named rule registry (`default` = the per-target generic set;
//! `default-tc` adds Use-Tensor-Core). `--explain-space` prints per-rule
//! applicability/error counters after tuning, plus intern-arena and
//! feature-cache hit counts (see README "Extending the search space").
//!
//! `--no-feature-cache` disables the per-canonical-trace feature cache
//! (see docs/ARCHITECTURE.md "Trace IR & interning"); cached vectors are
//! element-exact, so this only trades wall-clock — never results.
//!
//! `--alloc` selects the task scheduler's budget-allocation policy
//! (`greedy` is the historical default; `gradient` is the Ansor-style
//! improvement-slope policy; `round-robin` cycles), and `--objective`
//! the cost model's training objective (`mse` = historical squared-error
//! regression, `rank` = pairwise rank loss). The defaults reproduce the
//! pre-flag behaviour byte-for-byte, including database files (see
//! docs/ARCHITECTURE.md "Task scheduler & allocation policies").
//! ```

use metaschedule::ctx::TuneContext;
use metaschedule::db::{self, AnyDb, Database, DbStats};
use metaschedule::exp::{self, ExpConfig};
use metaschedule::graph;
use metaschedule::serve::{
    serve_batch, serve_snapshot, serve_watch, HttpConfig, HttpServer, ServeConfig, ServeOutcome,
    ServingCache,
};
use metaschedule::sim::Target;
use metaschedule::tir::{print_program, structural_hash, PrintOptions};
use metaschedule::trace::serde::{text_to_trace, trace_to_text};
use metaschedule::transfer::{TransferConfig, TransferPool};
use metaschedule::util::cli::Args;
use metaschedule::workloads;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // --verbosity wins over RUST_PALLAS_LOG; both gate the leveled
    // stderr diagnostics (product results on stdout are never gated).
    if let Some(v) = args.flag("verbosity") {
        match metaschedule::util::log::parse_level(v) {
            Some(level) => metaschedule::util::log::set_level(level),
            None => {
                metaschedule::log_error!("unknown verbosity {v} (error|warn|info|debug)");
                std::process::exit(2);
            }
        }
    }
    let cmd = args.positional.first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "list" => list(),
        "tune" => tune(&args),
        "tune-model" => tune_model(&args),
        "exp" => experiment(&args),
        "db" => db_cmd(&args),
        "serve" => serve_cmd(&args),
        "profile" => profile_cmd(&args),
        "pjrt-verify" => pjrt_verify(&args),
        _ => {
            metaschedule::log_error!(
                "usage: metaschedule <list|tune|tune-model|exp|db|serve|profile|pjrt-verify> [flags]\n\
                 see rust/src/main.rs header for details"
            );
            std::process::exit(2);
        }
    }
}

fn cfg_of(args: &Args) -> ExpConfig {
    ExpConfig {
        trials: args.flag_usize("trials", 64),
        seed: args.flag_u64("seed", 42),
        threads: args.flag_usize("threads", 0),
        db_path: args.flag("db").map(String::from),
        rules: args.flag("rules").map(String::from),
        mutators: args.flag("mutators").map(String::from),
        postprocs: args.flag("postprocs").map(String::from),
        alloc: alloc_of(args),
        objective: objective_of(args),
        // --no-transfer is the escape hatch: it wins over --transfer-from
        // so a scripted flag can be neutralized without editing the rest
        // of the command line.
        transfer_from: if args.has_switch("no-transfer") {
            None
        } else {
            args.flag("transfer-from").map(String::from)
        },
    }
}

/// Parse `--alloc` (default `greedy`, the historical behaviour), exiting
/// with a usage error on an unknown policy name.
fn alloc_of(args: &Args) -> metaschedule::search::Allocation {
    let spec = args.flag_or("alloc", "greedy");
    metaschedule::search::Allocation::parse(&spec).unwrap_or_else(|| {
        metaschedule::log_error!("unknown allocation policy {spec} (round-robin|greedy|gradient)");
        std::process::exit(2);
    })
}

/// Parse `--objective` (default `mse`, the historical behaviour), exiting
/// with a usage error on an unknown objective name.
fn objective_of(args: &Args) -> metaschedule::cost_model::Objective {
    let spec = args.flag_or("objective", "mse");
    metaschedule::cost_model::Objective::parse(&spec).unwrap_or_else(|| {
        metaschedule::log_error!("unknown cost-model objective {spec} (mse|rank)");
        std::process::exit(2);
    })
}

/// Build the tuning context from the `--rules`/`--mutators`/`--postprocs`
/// flags, exiting with a usage error (not a panic) on a bad spec.
fn ctx_of(args: &Args, target: &metaschedule::sim::Target) -> TuneContext {
    let rules = args.flag_or("rules", "default");
    let mutators = args.flag_or("mutators", "default");
    let postprocs = args.flag_or("postprocs", "default");
    match TuneContext::from_specs(target.clone(), &rules, &mutators, &postprocs) {
        Ok(ctx) => ctx,
        Err(e) => {
            metaschedule::log_error!("invalid tuning-context spec: {e}");
            std::process::exit(2);
        }
    }
}

fn target_of(args: &Args) -> Target {
    let name = args.flag_or("target", "cpu");
    Target::by_name(&name).unwrap_or_else(|| {
        metaschedule::log_error!("unknown target {name} (cpu|gpu|tpu)");
        std::process::exit(2);
    })
}

/// Resolve `--transfer-from` against `--no-transfer` and the destination
/// target, exiting with a usage error (not mid-tune) on a bad source.
/// Returns the canonical source target name.
fn transfer_source_of(args: &Args, dest: &Target) -> Option<String> {
    if args.has_switch("no-transfer") {
        return None;
    }
    let src = args.flag("transfer-from")?;
    let Some(source) = Target::by_name(src) else {
        metaschedule::log_error!("unknown transfer source target {src} (cpu|gpu|tpu)");
        std::process::exit(2);
    };
    if source.name == dest.name {
        metaschedule::log_error!(
            "--transfer-from {src}: source resolves to the destination target {} — \
             a target cannot donate priors to itself",
            dest.name
        );
        std::process::exit(2);
    }
    Some(source.name.to_string())
}

fn list() {
    println!("operator workloads (Appendix A.2):");
    for w in workloads::suite() {
        println!("  {:<4} {}", w.name, w.description);
    }
    println!("extra workloads:");
    for w in workloads::extras() {
        println!("  {:<11} {}", w.name, w.description);
    }
    println!("end-to-end models:");
    for m in graph::MODEL_NAMES {
        let g = graph::graph_by_name(m).unwrap();
        let per_op = graph::extract_tasks(&g.ops());
        let fused = graph::extract_fused_tasks(&g);
        println!(
            "  {:<14} {} unique tasks ({} graph-fused)",
            m,
            per_op.len(),
            fused.len()
        );
    }
}

fn tune(args: &Args) {
    let name = args.flag_or("workload", "GMM");
    let Some(w) = workloads::by_name(&name) else {
        metaschedule::log_error!("unknown workload {name}; see `metaschedule list`");
        std::process::exit(2);
    };
    let target = target_of(args);
    let mut cfg = cfg_of(args);
    let prog = (w.build)();
    println!("== tuning {} on {} ({} trials)", w.name, target.name, cfg.trials);
    let naive = metaschedule::sim::simulate(&prog, &target)
        .map(|r| r.total_s)
        .unwrap_or(f64::NAN);
    // Validate the context spec BEFORE opening the db: a typo'd --rules
    // must not create the file or append a registration line.
    let ctx = ctx_of(args, &target);
    println!("space: rules = {}", ctx.rule_set());
    // --no-feature-cache: score every candidate through a fresh feature
    // extraction instead of the per-canonical-trace cache. Cached vectors
    // are element-exact copies of fresh ones, so this only trades speed —
    // results and db files are byte-identical either way (the CI
    // intern-smoke job diffs them to prove it).
    if args.has_switch("no-feature-cache") {
        ctx.set_feature_cache_enabled(false);
        println!("feature cache disabled (--no-feature-cache; results are byte-identical)");
    }
    // --profile out.jsonl: record Chrome-trace spans of this tune
    // (observation-only; results are byte-identical with or without it).
    let profile = args.flag("profile").map(|p| {
        match metaschedule::telemetry::TraceSink::to_file(std::path::Path::new(p)) {
            Ok(sink) => {
                ctx.set_trace_sink(std::sync::Arc::clone(&sink));
                (p.to_string(), sink)
            }
            Err(e) => {
                metaschedule::log_error!("tune: cannot open profile {p}: {e}");
                std::process::exit(2);
            }
        }
    });
    // Same for the transfer flags: bad source names fail fast.
    let transfer_src = transfer_source_of(args, &target);
    // A donor archive without a source target is a mistake, not a cold
    // start — fail fast instead of silently ignoring the archive
    // (--no-transfer legitimately neutralizes the whole flag group).
    if args.flag("transfer-db").is_some() && transfer_src.is_none() && !args.has_switch("no-transfer") {
        metaschedule::log_error!(
            "tune: --transfer-db requires --transfer-from <target> (the archive alone names no source)"
        );
        std::process::exit(2);
    }
    let mut db = exp::open_db(&cfg);
    // Pre-register under the Figure-8 display name ("GMM", not the
    // program's internal "matmul") so `db top --workload GMM` finds it;
    // registration is idempotent and first name wins.
    let shash = structural_hash(&prog);
    db.register_workload(w.name, shash, target.name);
    // Build the transfer pool up front (from the read-only donor archive
    // when --transfer-db names one, otherwise from the tuning db itself)
    // so its selection stats can be reported next to the result.
    let pool = transfer_src.as_ref().map(|src| {
        let donors = args.flag("transfer-db");
        match donors {
            Some(dpath) => {
                if !std::path::Path::new(dpath).exists() {
                    metaschedule::log_error!("tune: no donor database at {dpath}");
                    std::process::exit(2);
                }
                let (mem, skipped) = match metaschedule::db::load_readonly_any(dpath) {
                    Ok(x) => x,
                    Err(e) => {
                        metaschedule::log_error!("tune: donor db: {e}");
                        std::process::exit(2);
                    }
                };
                if skipped > 0 {
                    metaschedule::log_warn!("tune: donor db {dpath}: recovered over {skipped} corrupt line(s)");
                }
                TransferPool::collect(&mem, shash, target.name, Some(src.as_str()), &ctx, TransferConfig::default())
            }
            None => TransferPool::collect(
                db.as_ref(),
                shash,
                target.name,
                Some(src.as_str()),
                &ctx,
                TransferConfig::default(),
            ),
        }
    });
    if args.has_switch("no-transfer") && args.flag("transfer-from").is_some() {
        println!("transfer disabled by --no-transfer (cold-start behaviour, bit-identical)");
    }
    // The pool above is THE transfer source for this run; clear the cfg
    // copy of the flag so no layer below can ever re-collect a second
    // pool from a different database.
    cfg.transfer_from = None;
    let r = exp::tune_with_ctx_db_pool(&prog, &ctx, &cfg, db.as_mut(), pool.as_ref());
    if r.warm_records > 0 {
        println!(
            "warm-start: resumed from {} db records (search continues from the recorded best)",
            r.warm_records
        );
    } else if cfg.db_path.is_some() {
        println!("cold start: no prior records for this workload in the db");
    }
    if r.stale_skipped > 0 {
        println!(
            "warm-start: skipped {} record(s) whose sim_version != {} (stale latencies are never replayed)",
            r.stale_skipped,
            metaschedule::sim::SIM_VERSION
        );
    }
    if let Some(pool) = &pool {
        let sources = if pool.source_targets.is_empty() {
            "none".to_string()
        } else {
            pool.source_targets.join(",")
        };
        println!(
            "transferred_records: {} re-measured on {} (pool: {} compatible donor record(s) from {}; {} incompatible skipped: {} sim, {} rules)",
            r.transferred_records,
            target.name,
            pool.len(),
            sources,
            pool.incompatible(),
            pool.incompatible_sim,
            pool.incompatible_rules
        );
    }
    println!(
        "naive {:.2} us -> tuned {:.2} us ({:.1}x) in {} trials",
        naive * 1e6,
        r.best_latency_s * 1e6,
        naive / r.best_latency_s,
        r.trials
    );
    if let Some(path) = &cfg.db_path {
        println!("db: committed {} new records to {path}", r.trials);
    }
    if args.has_switch("show-program") {
        println!("{}", print_program(&r.best_prog, PrintOptions::default()));
    }
    if args.has_switch("show-trace") {
        println!("{}", metaschedule::trace::serde::trace_to_text(&r.best_trace));
    }
    if args.has_switch("explain-space") {
        print!("{}", ctx.explain());
    }
    if let Some((path, sink)) = profile {
        match sink.finish() {
            Ok(n) => println!(
                "profile: wrote {n} trace event(s) to {path} (open in Perfetto or chrome://tracing)"
            ),
            Err(e) => {
                metaschedule::log_error!("tune: profile write to {path} failed: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// `profile <trace.jsonl>`: validate a `tune --profile` output file and
/// summarize it (event count, spans by category, slowest span names).
fn profile_cmd(args: &Args) {
    let Some(path) = args.positional.get(1) else {
        metaschedule::log_error!("usage: metaschedule profile <trace.json> (a `tune --profile` output)");
        std::process::exit(2);
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            metaschedule::log_error!("profile: read {path}: {e}");
            std::process::exit(1);
        }
    };
    let n = match metaschedule::telemetry::validate_trace(&text) {
        Ok(n) => n,
        Err(e) => {
            metaschedule::log_error!("profile: {path}: invalid trace: {e}");
            std::process::exit(1);
        }
    };
    println!("profile: {path}: valid Chrome trace, {n} event(s)");
    // Aggregate complete spans ("X") by name: count + total duration.
    use metaschedule::util::json::Json;
    let mut by_name: std::collections::BTreeMap<String, (usize, f64)> =
        std::collections::BTreeMap::new();
    let parsed = Json::parse(text.trim()).expect("validate_trace parsed this");
    if let Some(events) = parsed.as_arr() {
        for ev in events {
            if ev.get("ph").and_then(Json::as_str) != Some("X") {
                continue;
            }
            let name = ev.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
            let dur = ev.get("dur").and_then(Json::as_f64).unwrap_or(0.0);
            let e = by_name.entry(name).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dur;
        }
    }
    let mut rows: Vec<(String, usize, f64)> =
        by_name.into_iter().map(|(n, (c, d))| (n, c, d)).collect();
    rows.sort_by(|a, b| b.2.total_cmp(&a.2));
    for (name, count, total_us) in rows.iter().take(12) {
        println!("  {:<24} x{:<5} {:.1} ms total", name, count, total_us / 1e3);
    }
    println!("open in https://ui.perfetto.dev or chrome://tracing");
}

fn tune_model(args: &Args) {
    let name = args.flag_or("model", "bert-base");
    let target = target_of(args);
    let mut cfg = cfg_of(args);
    cfg.transfer_from = None; // scheduler path; see the note below
    let Some(ops) = graph::by_name(&name) else {
        metaschedule::log_error!("unknown model {name}; see `metaschedule list`");
        std::process::exit(2);
    };
    // Fail fast (exit 2, not a panic) on a bad spec before any tuning.
    let _ = ctx_of(args, &target);
    if args.flag("transfer-from").is_some() {
        // The task scheduler tunes many extracted tasks; per-task donor
        // pools are a future extension. Say so instead of silently
        // accepting the flag (cfg.transfer_from is cleared above).
        metaschedule::log_warn!("tune-model: --transfer-from applies to single-workload `tune` only; ignored here");
    }
    let fused = args.has_switch("fused");
    println!(
        "== tuning {name} on {} ({} trials/task{})",
        target.name,
        cfg.trials,
        if fused { ", graph-fused" } else { "" }
    );
    println!(
        "scheduler: alloc {} | objective {}",
        cfg.alloc.label(),
        cfg.objective.label()
    );
    if let Some(path) = &cfg.db_path {
        println!("db: {path} (per-task records shared; killed runs resume from it)");
    }
    let vendor = graph::vendor_e2e(&ops, &target);
    let (ms, alloc_report) = if fused {
        // Tune over the fused operator DAG: fewer, larger tasks.
        let g = graph::graph_by_name(&name).expect("by_name succeeded above");
        let groups = graph::fuse(&g);
        let tasks = graph::extract_fused_tasks(&g);
        println!("{}", graph::summarize(&groups));
        println!(
            "tasks: {} fused (vs {} per-op)",
            tasks.len(),
            graph::extract_tasks(&ops).len()
        );
        exp::fig9::metaschedule_fused_e2e_report(&name, &target, &cfg)
    } else {
        exp::fig9::metaschedule_e2e_report(&name, &target, &cfg)
    };
    // Per-task budget shares: where the scheduler actually spent the
    // trials (the CI sched-smoke job greps these lines to prove the
    // gradient policy allocates non-uniformly).
    for share in &alloc_report.per_task {
        println!(
            "alloc[{}] task {}: {} trials over {} round(s), best {:.2} us (weight {}){}",
            alloc_report.policy,
            share.name,
            share.trials,
            share.rounds,
            share.best_latency_s * 1e6,
            share.weight,
            if share.saturated { ", saturated" } else { "" }
        );
    }
    if alloc_report.early_stop {
        println!(
            "alloc[{}]: early stop with {} of {} trials spent (all tasks saturated)",
            alloc_report.policy, alloc_report.spent, alloc_report.total_trials
        );
    }
    println!(
        "vendor (PyTorch-class) e2e {:.3} ms; MetaSchedule e2e {:.3} ms ({:.2}x)",
        vendor * 1e3,
        ms * 1e3,
        vendor / ms
    );
}

fn experiment(args: &Args) {
    let which = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".into());
    let cfg = cfg_of(args);
    // Validate context specs up front against both experiment targets so
    // a typo exits 2 with a usage error instead of panicking mid-run
    // (ExpConfig::context panics by contract — the CLI validates here).
    for target in [Target::cpu_avx512(), Target::gpu()] {
        let _ = ctx_of(args, &target);
    }
    // Same for a transfer source: validate the name only (experiments
    // span both targets, so source == destination arms simply collect an
    // empty pool rather than erroring).
    if let Some(src) = args.flag("transfer-from") {
        if Target::by_name(src).is_none() {
            metaschedule::log_error!("unknown transfer source target {src} (cpu|gpu|tpu)");
            std::process::exit(2);
        }
    }
    let out = args.flag("out").map(|s| s.to_string());
    let mut reports = Vec::new();
    match which.as_str() {
        "fig8" => {
            reports.push(exp::fig8::run(&Target::cpu_avx512(), &cfg, None));
            reports.push(exp::fig8::run(&Target::gpu(), &cfg, None));
        }
        "fig9" => {
            reports.push(exp::fig9::run(&Target::cpu_avx512(), &cfg, None));
            reports.push(exp::fig9::run(&Target::gpu(), &cfg, None));
        }
        "fig10a" => reports.push(exp::fig10::run_10a(&cfg)),
        "fig10b" => reports.push(exp::fig10::run_10b(&cfg)),
        "table1" => reports.push(exp::table1::run(&Target::cpu_avx512(), &cfg, None)),
        "all" => {
            reports.push(exp::fig8::run(&Target::cpu_avx512(), &cfg, None));
            reports.push(exp::fig8::run(&Target::gpu(), &cfg, None));
            reports.push(exp::fig9::run(&Target::cpu_avx512(), &cfg, None));
            reports.push(exp::fig9::run(&Target::gpu(), &cfg, None));
            reports.push(exp::fig10::run_10a(&cfg));
            reports.push(exp::fig10::run_10b(&cfg));
            reports.push(exp::table1::run(&Target::cpu_avx512(), &cfg, None));
        }
        other => {
            metaschedule::log_error!("unknown experiment {other}");
            std::process::exit(2);
        }
    }
    for r in &reports {
        r.print();
        if let Some(path) = &out {
            if let Err(e) = r.write(path) {
                metaschedule::log_error!("failed writing {path}: {e}");
            }
        }
    }
}

/// `db stats` / `db top` / `db compact`: inspect or GC a JSONL tuning
/// database.
fn db_cmd(args: &Args) {
    let sub = args.positional.get(1).cloned().unwrap_or_else(|| "stats".into());
    let Some(path) = args.flag("db") else {
        metaschedule::log_error!("db: --db <path.jsonl> required");
        std::process::exit(2);
    };
    if sub == "compact" {
        // --stale-rules drops every record of a retired rule set (the
        // ROADMAP "registry-driven space invalidation" item): pass the
        // full label from `db stats`, its name-list part, its #digest
        // part, or `-` for pre-provenance records.
        let stale_rule_sets: Vec<String> = args
            .flag("stale-rules")
            .map(|s| if s == "-" { String::new() } else { s.to_string() })
            .into_iter()
            .collect();
        let policy = db::CompactionPolicy {
            top_k: args.flag_usize("k", db::compact::DEFAULT_TOP_K),
            stale_rule_sets,
        };
        // --repair: also drop corrupt lines recovered over at open and
        // confirm --stale-rules destruction (refused otherwise, so data
        // loss is never a surprise). Sharded dirs compact their shards
        // in parallel (--threads 0 = one per shard).
        match db::compact_any(path, &policy, args.has_switch("repair"), args.flag_usize("threads", 0)) {
            Ok(report) => println!("{}", report.render(path)),
            Err(e) => {
                metaschedule::log_error!("db compact: {e}");
                std::process::exit(1);
            }
        }
        return;
    }
    if sub == "migrate" {
        // Single-file -> sharded conversion; the source is read-only
        // (kept as a backup until the operator deletes it).
        let Some(out) = args.flag("out") else {
            metaschedule::log_error!("db migrate: --out <dir> required (the sharded directory to create)");
            std::process::exit(2);
        };
        let shards = args.flag_usize("shards", db::DEFAULT_SHARDS);
        match db::migrate_from_file(path, out, shards) {
            Ok((sdb, skipped)) => {
                if skipped > 0 {
                    metaschedule::log_warn!("db migrate: source carried {skipped} corrupt line(s); not copied");
                }
                println!(
                    "migrated {path} -> {out}: {} workload(s), {} record(s) across {} shard(s)",
                    sdb.workload_entries().len(),
                    sdb.num_records(),
                    sdb.num_shards()
                );
            }
            Err(e) => {
                metaschedule::log_error!("db migrate: {e}");
                std::process::exit(1);
            }
        }
        return;
    }
    if sub == "transfer-candidates" {
        transfer_candidates_cmd(args, path);
        return;
    }
    let db = match AnyDb::open(path) {
        Ok(db) => db,
        Err(e) => {
            metaschedule::log_error!("db: {e}");
            std::process::exit(1);
        }
    };
    report_skipped(&db);
    match sub.as_str() {
        "stats" => {
            println!("db: {} ({} bytes, {} shard(s))", path, db.file_len(), db.num_shards());
            print!("{}", DbStats::compute(&db).render());
        }
        "top" => {
            let wname = args.flag_or("workload", "GMM");
            let k = args.flag_usize("k", 5);
            let entries: Vec<_> = db.workload_entries().into_iter().filter(|e| e.name == wname).collect();
            if entries.is_empty() {
                metaschedule::log_error!("db: no workload named {wname}; see `metaschedule db stats`");
                std::process::exit(1);
            }
            for entry in entries {
                let top = db.query_top_k(entry.id, k);
                println!(
                    "== top {} of {} records for {} on {} (shash {:016x})",
                    top.len(),
                    db.records_for(entry.id).len(),
                    entry.name,
                    entry.target,
                    entry.shash
                );
                for (rank, rec) in top.iter().enumerate() {
                    let lat = rec.best_latency().unwrap_or(f64::NAN);
                    println!(
                        "# rank {} | latency {:.3} us | seed {} | round {} | cand {:016x}",
                        rank + 1,
                        lat * 1e6,
                        rec.seed,
                        rec.round,
                        rec.cand_hash
                    );
                    println!(
                        "# provenance: sim {} | rules {}",
                        rec.sim_version,
                        if rec.rule_set.is_empty() { "-" } else { &rec.rule_set }
                    );
                    let text = trace_to_text(&rec.trace);
                    print!("{text}");
                    // The printed trace must parse back — the db's whole
                    // point is that records survive round trips.
                    match text_to_trace(&text) {
                        Ok(t) if t == rec.trace => println!("# trace round-trips OK"),
                        Ok(_) => println!("# WARNING: trace round-trip mismatch"),
                        Err(e) => println!("# WARNING: trace does not re-parse: {e}"),
                    }
                }
            }
        }
        other => {
            metaschedule::log_error!(
                "usage: metaschedule db <stats|top|compact|migrate|transfer-candidates> --db <path> [--workload W] [-k N] (got {other})"
            );
            std::process::exit(2);
        }
    }
}

/// `db transfer-candidates`: what would `tune --target <dest>
/// --transfer-from <src>` inject? Lists every donor registration of the
/// workload and each donor record's compatibility verdict. The archive
/// is loaded read-only (never created, never opened for append) — an
/// inspection must work off a read-only mount, and a typo'd path must
/// error instead of leaving an empty file behind.
fn transfer_candidates_cmd(args: &Args, path: &str) {
    let wname = args.flag_or("workload", "GMM");
    let Some(w) = workloads::by_name(&wname) else {
        metaschedule::log_error!("db: unknown workload {wname}; see `metaschedule list`");
        std::process::exit(1);
    };
    let dest = target_of(args);
    let from = args.flag("from").map(|src| match Target::by_name(src) {
        Some(t) => t.name.to_string(),
        None => {
            metaschedule::log_error!("db: unknown source target {src} (cpu|gpu|tpu)");
            std::process::exit(2);
        }
    });
    let ctx = ctx_of(args, &dest);
    if !std::path::Path::new(path).exists() {
        metaschedule::log_error!("db: no database at {path}");
        std::process::exit(1);
    }
    let (db, skipped) = match metaschedule::db::load_readonly_any(path) {
        Ok(x) => x,
        Err(e) => {
            metaschedule::log_error!("db: {e}");
            std::process::exit(1);
        }
    };
    if skipped > 0 {
        metaschedule::log_warn!("db: recovered over {skipped} corrupt line(s); `db compact --repair` drops them");
    }
    let prog = (w.build)();
    let shash = structural_hash(&prog);
    // One `k` drives both the per-record listing and the pool summary
    // below, with the same semantics as `TransferPool::collect`: `k`
    // caps *compatible* records per donor (incompatible ones are always
    // listed with their reason — they are the diagnostic payload), so
    // the listing and the pool counts never disagree about what is in
    // play.
    let k = args.flag_usize("k", TransferConfig::default().per_source_top_k);
    println!("== transfer candidates for {} -> {} (shash {:016x})", wname, dest.name, shash);
    let donors = db.find_workload_any_target(shash);
    let mut shown = 0usize;
    for entry in donors {
        if entry.target == dest.name {
            continue;
        }
        if let Some(src) = &from {
            if &entry.target != src {
                continue;
            }
        }
        let all = db.query_top_k(entry.id, usize::MAX);
        println!(
            "donor [{}] {} on {}: {} successful record(s)",
            entry.id,
            entry.name,
            entry.target,
            all.len()
        );
        let mut compat_listed = 0usize;
        let mut over_cap = 0usize;
        for rec in all {
            let incompatible_sim = rec.sim_version != metaschedule::sim::SIM_VERSION;
            let incompatible_rules = !incompatible_sim && !ctx.transfer_compatible(&rec.rule_set);
            let verdict = if incompatible_sim {
                format!("INCOMPATIBLE (sim {} != {})", rec.sim_version, metaschedule::sim::SIM_VERSION)
            } else if incompatible_rules {
                "INCOMPATIBLE (rule set not expressible here)".to_string()
            } else if compat_listed >= k {
                over_cap += 1;
                continue; // compatible but beyond the per-source cap: not in the pool
            } else {
                compat_listed += 1;
                "compatible (in pool)".to_string()
            };
            println!(
                "  {:.3} us | rules {} | {}",
                rec.best_latency().unwrap_or(f64::NAN) * 1e6,
                if rec.rule_set.is_empty() { "-" } else { &rec.rule_set },
                verdict
            );
            shown += 1;
        }
        if over_cap > 0 {
            println!("  (+{over_cap} compatible record(s) beyond the per-source cap -k {k}; not in the pool)");
        }
    }
    let pool = TransferPool::collect(
        &db,
        shash,
        dest.name,
        from.as_deref(),
        &ctx,
        TransferConfig { per_source_top_k: k, ..TransferConfig::default() },
    );
    println!(
        "pool: {} compatible donor record(s) from [{}]; {} incompatible ({} sim, {} rules)",
        pool.len(),
        pool.source_targets.join(","),
        pool.incompatible(),
        pool.incompatible_sim,
        pool.incompatible_rules
    );
    if shown == 0 {
        println!("(no donor records — tune this workload on another target first)");
    }
}

/// Warn (to stderr, so greppable stdout stays clean) when an open
/// recovered over corrupt lines (any shard, for a sharded db).
fn report_skipped(db: &AnyDb) {
    if db.skipped_lines() > 0 {
        metaschedule::log_warn!(
            "db: recovered over {} corrupt line(s); `db compact` will drop them",
            db.skipped_lines()
        );
        for note in db.skip_notes() {
            metaschedule::log_warn!("db:   {note}");
        }
    }
}

/// `serve`: answer workload lookups from an indexed snapshot of the db.
fn serve_cmd(args: &Args) {
    let Some(path) = args.flag("db") else {
        metaschedule::log_error!("serve: --db <path> required (a .jsonl file or a sharded directory)");
        std::process::exit(2);
    };
    let target = target_of(args);
    let cfg = ServeConfig {
        miss_trials: args.flag_usize("miss-trials", 16),
        threads: args.flag_usize("threads", 0),
        seed: args.flag_u64("seed", 42),
        top_k: args.flag_usize("k", ServingCache::DEFAULT_TOP_K),
    };
    // Network mode: serve over HTTP until a GET /shutdown arrives. Needs
    // no workload names — clients name workloads per request.
    if let Some(addr) = args.flag("listen") {
        let db = match AnyDb::open(path) {
            Ok(db) => db,
            Err(e) => {
                metaschedule::log_error!("serve: {e}");
                std::process::exit(1);
            }
        };
        report_skipped(&db);
        let http = HttpConfig {
            addr: addr.to_string(),
            workers: args.flag_usize("workers", 4),
            max_pending: args.flag_usize("max-pending", 64),
            max_inflight_tunes: args.flag_usize("max-inflight", 1),
            access_log: args.flag("access-log").map(String::from),
            serve: cfg,
        };
        let server = match HttpServer::bind(http, target.clone()) {
            Ok(s) => s,
            Err(e) => {
                metaschedule::log_error!("serve: {e}");
                std::process::exit(1);
            }
        };
        println!(
            "== listening on http://{} ({} record(s), {} shard(s) from {path}, target {})",
            server.local_addr(),
            db.num_records(),
            db.num_shards(),
            target.name
        );
        println!(
            "   routes: GET /lookup?workload=NAME[&target=T] | POST /batch | GET /stats | GET /metrics | GET /healthz | GET /shutdown"
        );
        let r = server.run(db);
        println!(
            "served {} request(s): {} hit(s), {} miss(es), {} tuned, {} tune(s) rejected, {} bad request(s)",
            r.requests, r.hits, r.misses, r.tuned, r.tune_rejected, r.bad_requests
        );
        return;
    }
    // Batch mode: positional names after `serve`, plus `--workloads A,B`.
    let mut names: Vec<String> = args.positional.iter().skip(1).cloned().collect();
    names.extend(args.flag_csv("workloads"));
    if names.is_empty() {
        metaschedule::log_error!(
            "serve: name at least one workload (positional or --workloads GMM,SFM), or --listen <addr>"
        );
        std::process::exit(2);
    }
    fn serve_fail(e: String) -> Vec<ServeOutcome> {
        metaschedule::log_error!("serve: {e}");
        std::process::exit(2);
    }
    if args.has_switch("watch") {
        // Watch mode is read-only by construction (reload + re-serve on
        // change; tune-on-miss inside a watcher would tune in a loop).
        if args.flag("miss-trials").is_some() && cfg.miss_trials > 0 {
            metaschedule::log_warn!(
                "serve: --watch is read-only; --miss-trials {} ignored (misses stay misses)",
                cfg.miss_trials
            );
        }
        let poll_ms = args.flag_u64("poll-ms", 500);
        println!(
            "== watching {path} on {} (read-only; poll {poll_ms} ms; re-serving on change)",
            target.name
        );
        let res = serve_watch(
            &names,
            &target,
            path,
            cfg.top_k,
            poll_ms,
            None,
            &mut |round, outcomes| {
                if round == 0 {
                    println!("-- initial serve");
                } else {
                    println!("-- db changed (refresh {round})");
                }
                print_outcomes(outcomes);
            },
        );
        if let Err(e) = res {
            metaschedule::log_error!("serve: {e}");
            std::process::exit(1);
        }
        return;
    }
    let outcomes = if cfg.miss_trials == 0 {
        // Report-only: load the snapshot without ever opening the file
        // for writing, so serving works off a read-only mount.
        let (cache, skipped) = match ServingCache::load(path, cfg.top_k) {
            Ok(x) => x,
            Err(e) => {
                metaschedule::log_error!("serve: {e}");
                std::process::exit(1);
            }
        };
        if skipped > 0 {
            metaschedule::log_warn!(
                "serve: recovered over {skipped} corrupt line(s); `db compact --repair` drops them"
            );
        }
        println!(
            "== serving {} workload(s) on {} from {path} ({} records indexed, read-only)",
            names.len(),
            target.name,
            cache.num_records()
        );
        serve_snapshot(&names, &target, &cache).unwrap_or_else(serve_fail)
    } else {
        let mut db = match AnyDb::open(path) {
            Ok(db) => db,
            Err(e) => {
                metaschedule::log_error!("serve: {e}");
                std::process::exit(1);
            }
        };
        report_skipped(&db);
        println!(
            "== serving {} workload(s) on {} from {path} ({} records on file)",
            names.len(),
            target.name,
            db.num_records()
        );
        serve_batch(&names, &target, &mut db, &cfg).unwrap_or_else(serve_fail)
    };
    print_outcomes(&outcomes);
}

/// Shared outcome rendering for one-shot and `--watch` serving.
fn print_outcomes(outcomes: &[ServeOutcome]) {
    let mut hits = 0;
    for o in outcomes {
        match (o.hit, o.latency_s) {
            (true, Some(lat)) => {
                hits += 1;
                println!("  {}: HIT  {:.2} us (replayed best of {} records)", o.workload, lat * 1e6, o.records);
            }
            (true, None) => {
                hits += 1;
                println!("  {}: HIT (recorded schedule invalid on simulator)", o.workload);
            }
            (false, Some(lat)) => println!(
                "  {}: MISS -> tuned {:.2} us in {} trials (committed to db)",
                o.workload,
                lat * 1e6,
                o.trials
            ),
            (false, None) => println!("  {}: MISS (report-only; tune with --miss-trials N)", o.workload),
        }
    }
    println!("served {}: {} hit(s), {} miss(es)", outcomes.len(), hits, outcomes.len() - hits);
}

fn pjrt_verify(args: &Args) {
    let dir = args.flag_or("artifacts", "artifacts");
    let variants = metaschedule::runtime::scan_variants(std::path::Path::new(&dir));
    if variants.is_empty() {
        metaschedule::log_error!("no artifacts under {dir}; run `make artifacts` first");
        std::process::exit(1);
    }
    let mut runner = match metaschedule::runtime::PjrtRunner::new(&dir) {
        Ok(r) => r,
        Err(e) => {
            metaschedule::log_error!("cannot start PJRT runtime: {e}");
            std::process::exit(1);
        }
    };
    println!("platform: {}", runner.platform());
    for v in &variants {
        let err = runner.verify_gmm(*v, 128, 128, 128).expect("execution");
        let status = if err < 1e-3 { "OK" } else { "FAIL" };
        println!("  {:<30} max|err| = {err:.2e}  {status}", v.artifact_name());
        assert!(err < 1e-3, "artifact {v:?} numerics diverge");
    }
    println!("all {} variants verified against host matmul", variants.len());
}
