//! Integration tests for the AOT -> PJRT measurement loop. These need the
//! artifacts built by `make artifacts`; they skip (pass vacuously, with a
//! note) when the artifacts are absent so `cargo test` works standalone.

use metaschedule::cost_model::GbtCostModel;
use metaschedule::ctx::TuneContext;
use metaschedule::runtime::{
    scan_variants, PallasTileModule, PjrtGmmMeasurer, PjrtRunner, TileVariant,
};
use metaschedule::search::{EvolutionarySearch, Measurer, SearchConfig};
use metaschedule::sim::Target;
use metaschedule::workloads;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn artifacts_scan_finds_grid() {
    let Some(dir) = artifacts_dir() else { return };
    let vs = scan_variants(&dir);
    assert!(vs.len() >= 4, "found {} variants", vs.len());
    assert!(vs.contains(&TileVariant { bm: 32, bn: 32, bk: 32 }));
}

#[test]
fn pjrt_executes_gmm_correctly() {
    let Some(dir) = artifacts_dir() else { return };
    let mut runner = PjrtRunner::new(dir).unwrap();
    assert_eq!(runner.platform().to_lowercase(), "cpu");
    // Correctness of the Pallas-tiled artifact vs host matmul.
    let err = runner
        .verify_gmm(TileVariant { bm: 32, bn: 32, bk: 32 }, 128, 128, 128)
        .unwrap();
    assert!(err < 1e-3, "max err {err}");
    // A second variant compiles from cache-miss and matches too.
    let err = runner
        .verify_gmm(TileVariant { bm: 64, bn: 64, bk: 64 }, 128, 128, 128)
        .unwrap();
    assert!(err < 1e-3, "max err {err}");
}

#[test]
fn pjrt_timing_is_positive_and_cached() {
    let Some(dir) = artifacts_dir() else { return };
    let mut m = PjrtGmmMeasurer::new(dir, 128, 128, 128).unwrap();
    let v = TileVariant { bm: 32, bn: 32, bk: 32 };
    let t1 = m.time_variant(v).unwrap();
    assert!(t1 > 0.0 && t1 < 1.0, "{t1}");
    let before = m.runner.measurements;
    let t2 = m.time_variant(v).unwrap();
    assert_eq!(t1, t2, "cached");
    assert_eq!(m.runner.measurements, before);
}

#[test]
fn search_over_real_pjrt_measurements() {
    // The end-to-end loop: MetaSchedule search where f(e) is real wall
    // clock of the AOT Pallas variants.
    let Some(dir) = artifacts_dir() else { return };
    let mut measurer = PjrtGmmMeasurer::new(dir, 128, 128, 128).unwrap();
    let prog = workloads::matmul(1, 128, 128, 128);
    let ctx = TuneContext::from_rules(
        vec![Box::new(PallasTileModule::new())],
        Target::cpu_avx512(),
    );
    let cfg = SearchConfig {
        population: 16,
        generations: 2,
        num_trials: 12,
        measure_batch: 6,
        ..SearchConfig::default()
    };
    let mut model = GbtCostModel::new();
    let r = EvolutionarySearch::new(cfg).tune(&prog, &ctx, &mut model, &mut measurer, 7);
    assert!(r.best_latency_s > 0.0 && r.best_latency_s < 1.0);
    assert!(measurer.count() > 0);
    // The chosen schedule's tile parses back out.
    let t = metaschedule::runtime::tile_of(&r.best_prog).unwrap();
    assert_eq!(128 % t.bm, 0);
}
