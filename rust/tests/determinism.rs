//! The parallel pipeline's determinism contract: the OS-thread count is
//! an execution knob, never an input to the search. `(seed, threads=1)`
//! and `(seed, threads=N)` must produce bit-identical tuning outcomes —
//! best trace, best latency, trial count, and the full tuning curve.
//! The tuning database extends the contract: the database *contents* are
//! an input to the search (a warm run differs from a cold run), but for
//! a fixed starting database the outcome is still thread-count-invariant
//! and repeat-run reproducible.

use metaschedule::cost_model::GbtCostModel;
use metaschedule::ctx::TuneContext;
use metaschedule::db::{Database, InMemoryDb};
use metaschedule::search::{EvolutionarySearch, SearchConfig, SimMeasurer, TaskScheduler};
use metaschedule::sim::Target;
use metaschedule::tir::structural_hash;
use metaschedule::trace::serde::trace_to_text;
use metaschedule::workloads;

fn cfg(trials: usize, threads: usize) -> SearchConfig {
    SearchConfig {
        population: 24,
        generations: 3,
        num_trials: trials,
        measure_batch: 8,
        threads,
        ..SearchConfig::default()
    }
}

#[test]
fn matmul_search_identical_across_thread_counts() {
    let target = Target::cpu_avx512();
    let prog = workloads::matmul(1, 128, 128, 128);
    let ctx = TuneContext::generic(target.clone());
    let run = |threads: usize| {
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        EvolutionarySearch::new(cfg(48, threads)).tune(
            &prog,
            &ctx,
            &mut model,
            &mut measurer,
            42,
        )
    };
    let serial = run(1);
    for threads in [2, 4] {
        let parallel = run(threads);
        assert_eq!(
            serial.best_latency_s, parallel.best_latency_s,
            "latency diverged at {threads} threads"
        );
        assert_eq!(
            structural_hash(&serial.best_prog),
            structural_hash(&parallel.best_prog),
            "best program diverged at {threads} threads"
        );
        assert_eq!(
            trace_to_text(&serial.best_trace),
            trace_to_text(&parallel.best_trace),
            "best trace diverged at {threads} threads"
        );
        assert_eq!(serial.trials, parallel.trials);
        assert_eq!(serial.curve, parallel.curve, "curve diverged at {threads} threads");
    }
}

#[test]
fn gpu_space_identical_across_thread_counts() {
    // Same contract on the GPU design space (thread-binding decisions in
    // the traces, different mutation surface).
    let target = Target::gpu();
    let prog = workloads::matmul(1, 128, 128, 128);
    let ctx = TuneContext::generic(target.clone());
    let run = |threads: usize| {
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        EvolutionarySearch::new(cfg(32, threads)).tune(
            &prog,
            &ctx,
            &mut model,
            &mut measurer,
            7,
        )
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.best_latency_s, b.best_latency_s);
    assert_eq!(a.curve, b.curve);
}

#[test]
fn task_scheduler_identical_across_thread_counts() {
    // Warmup rounds run task-parallel; merged results must match the
    // serial schedule, per task, including trial accounting.
    let target = Target::cpu_avx512();
    let ctx = TuneContext::generic(target.clone());
    let tasks = vec![
        metaschedule::search::Task {
            name: "gmm".into(),
            prog: workloads::matmul(1, 128, 128, 128),
            weight: 3,
        },
        metaschedule::search::Task {
            name: "sfm".into(),
            prog: workloads::softmax(1, 128, 128),
            weight: 1,
        },
    ];
    let run = |threads: usize| {
        let mut measurer = SimMeasurer::new(target.clone());
        let ts = TaskScheduler::new(cfg(0, threads));
        ts.tune_tasks(&tasks, &ctx, &mut measurer, 64, 11)
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.best_latency_s, b.best_latency_s, "task {} diverged", a.task);
        assert_eq!(a.trials, b.trials);
        assert_eq!(
            structural_hash(&a.best_prog),
            structural_hash(&b.best_prog)
        );
    }
}

#[test]
fn warm_start_deterministic_across_thread_counts() {
    // (seed, cold DB) and (seed, warm DB) are *different* searches, but
    // each must be deterministic in its own right: identical across
    // thread counts and across repeat runs from the same starting DB.
    let target = Target::cpu_avx512();
    let prog = workloads::matmul(1, 128, 128, 128);
    let ctx = TuneContext::generic(target.clone());
    let run = |db: &mut dyn Database, threads: usize| {
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        EvolutionarySearch::new(cfg(32, threads)).tune_db(&prog, &ctx, &mut model, &mut measurer, db, 13)
    };
    // Cold phase, serial vs parallel: identical results AND identical
    // database contents (records are committed in fold order).
    let mut db_serial = InMemoryDb::new();
    let mut db_parallel = InMemoryDb::new();
    let cold_a = run(&mut db_serial, 1);
    let cold_b = run(&mut db_parallel, 4);
    assert_eq!(cold_a.best_latency_s, cold_b.best_latency_s);
    assert_eq!(cold_a.curve, cold_b.curve);
    assert_eq!(db_serial.num_records(), db_parallel.num_records());
    let dump = |db: &InMemoryDb| -> Vec<String> {
        db.records_for(0).iter().map(|r| r.to_json().to_string()).collect()
    };
    assert_eq!(dump(&db_serial), dump(&db_parallel), "committed records diverged with threads");

    // Warm phase from identical snapshots: same contract, and the warm
    // result can only improve on the recorded best.
    let mut warm_serial = db_serial.clone();
    let mut warm_parallel = db_serial.clone();
    let warm_a = run(&mut warm_serial, 1);
    let warm_b = run(&mut warm_parallel, 4);
    assert!(warm_a.warm_records > 0);
    assert_eq!(warm_a.best_latency_s, warm_b.best_latency_s, "warm run diverged with threads");
    assert_eq!(warm_a.curve, warm_b.curve);
    assert_eq!(
        trace_to_text(&warm_a.best_trace),
        trace_to_text(&warm_b.best_trace)
    );
    assert_eq!(dump(&warm_serial), dump(&warm_parallel));
    assert!(warm_a.best_latency_s <= cold_a.best_latency_s);

    // Repeat warm run from the same snapshot: byte-identical.
    let mut warm_again = db_serial.clone();
    let warm_c = run(&mut warm_again, 1);
    assert_eq!(warm_a.best_latency_s, warm_c.best_latency_s);
    assert_eq!(warm_a.curve, warm_c.curve);
}

#[test]
fn task_scheduler_with_shared_db_identical_across_thread_counts() {
    // Warmup rounds commit through the SharedDb from worker threads; the
    // per-task results must still match the serial schedule for a fixed
    // starting database — cold and warm.
    let target = Target::cpu_avx512();
    let ctx = TuneContext::generic(target.clone());
    let tasks = vec![
        metaschedule::search::Task {
            name: "gmm".into(),
            prog: workloads::matmul(1, 128, 128, 128),
            weight: 3,
        },
        metaschedule::search::Task {
            name: "sfm".into(),
            prog: workloads::softmax(1, 128, 128),
            weight: 1,
        },
    ];
    let run = |db: &mut dyn Database, threads: usize| {
        let mut measurer = SimMeasurer::new(target.clone());
        let ts = TaskScheduler::new(cfg(0, threads));
        ts.tune_tasks_with_db(&tasks, &ctx, &mut measurer, db, 64, 17)
    };
    let mut cold = InMemoryDb::new();
    let serial = run(&mut cold.clone(), 1);
    let parallel = run(&mut cold, 4);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.best_latency_s, b.best_latency_s, "cold task {} diverged", a.task);
        assert_eq!(a.trials, b.trials);
    }
    // Warm pass from the (parallel-written) database.
    let warm_serial = run(&mut cold.clone(), 1);
    let warm_parallel = run(&mut cold.clone(), 4);
    for (a, b) in warm_serial.iter().zip(&warm_parallel) {
        assert_eq!(a.best_latency_s, b.best_latency_s, "warm task {} diverged", a.task);
        assert_eq!(structural_hash(&a.best_prog), structural_hash(&b.best_prog));
    }
    for (cold_r, warm_r) in parallel.iter().zip(&warm_serial) {
        assert!(warm_r.best_latency_s <= cold_r.best_latency_s);
    }
}

#[test]
fn default_scheduler_database_bytes_identical_to_explicit_greedy_mse() {
    // The pluggable allocation/objective refactor must be invisible at
    // the defaults: a scheduler left at its defaults and one explicitly
    // configured `greedy` + `mse` write byte-identical database files
    // (the CLI's `--alloc greedy --objective mse` resolves to exactly
    // this configuration).
    use metaschedule::cost_model::Objective;
    use metaschedule::db::JsonFileDb;
    use metaschedule::search::Allocation;

    let dir = std::env::temp_dir().join(format!("ms-alloc-default-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let target = Target::cpu_avx512();
    let ctx = TuneContext::generic(target.clone());
    let tasks = vec![
        metaschedule::search::Task {
            name: "gmm".into(),
            prog: workloads::matmul(1, 128, 128, 128),
            weight: 3,
        },
        metaschedule::search::Task {
            name: "sfm".into(),
            prog: workloads::softmax(1, 128, 128),
            weight: 1,
        },
    ];
    let run = |tag: &str, explicit: bool| {
        let mut measurer = SimMeasurer::new(target.clone());
        let mut ts = TaskScheduler::new(cfg(0, 1));
        if explicit {
            ts.allocation = Allocation::Greedy;
            ts.objective = Objective::Regression;
        }
        let db_path = dir.join(format!("{tag}.db.jsonl"));
        let mut db = JsonFileDb::open(&db_path).unwrap();
        // Budget larger than the warmup share so allocation rounds run —
        // the comparison must cover the policy loop, not just warmup.
        let results = ts.tune_tasks_with_db(&tasks, &ctx, &mut measurer, &mut db, 128, 11);
        drop(db);
        (results, std::fs::read(&db_path).unwrap())
    };
    let (default_res, default_bytes) = run("default", false);
    let (explicit_res, explicit_bytes) = run("explicit", true);
    for (a, b) in default_res.iter().zip(&explicit_res) {
        assert_eq!(a.best_latency_s, b.best_latency_s, "task {} diverged", a.task);
        assert_eq!(a.trials, b.trials);
    }
    assert_eq!(
        default_bytes, explicit_bytes,
        "explicit greedy+mse wrote different database bytes than the default"
    );
    // Defaults never stamp an objective: the optional `obj` field is the
    // one place the new provenance could leak into default-config files.
    let text = String::from_utf8(default_bytes).unwrap();
    assert!(!text.contains("\"obj\""), "default config stamped an objective: {text}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gradient_rank_scheduler_identical_across_thread_counts() {
    // The new policy/objective pair inherits the full determinism
    // contract: thread count changes wall-clock only — results and
    // database bytes stay identical.
    use metaschedule::cost_model::Objective;
    use metaschedule::db::JsonFileDb;
    use metaschedule::search::Allocation;

    let dir = std::env::temp_dir().join(format!("ms-gradrank-determinism-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let target = Target::cpu_avx512();
    let ctx = TuneContext::generic(target.clone());
    let tasks = vec![
        metaschedule::search::Task {
            name: "gmm".into(),
            prog: workloads::matmul(1, 128, 128, 128),
            weight: 3,
        },
        metaschedule::search::Task {
            name: "sfm".into(),
            prog: workloads::softmax(1, 128, 128),
            weight: 1,
        },
    ];
    let run = |tag: &str, threads: usize| {
        let mut measurer = SimMeasurer::new(target.clone());
        let mut ts = TaskScheduler::new(cfg(0, threads));
        ts.allocation = Allocation::Gradient;
        ts.objective = Objective::PairwiseRank;
        let db_path = dir.join(format!("{tag}.db.jsonl"));
        let mut db = JsonFileDb::open(&db_path).unwrap();
        let results = ts.tune_tasks_with_db(&tasks, &ctx, &mut measurer, &mut db, 128, 17);
        drop(db);
        (results, std::fs::read(&db_path).unwrap())
    };
    let (serial, serial_bytes) = run("t1", 1);
    let (parallel, parallel_bytes) = run("t4", 4);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.best_latency_s, b.best_latency_s, "task {} diverged", a.task);
        assert_eq!(a.trials, b.trials);
        assert_eq!(structural_hash(&a.best_prog), structural_hash(&b.best_prog));
    }
    assert_eq!(
        serial_bytes, parallel_bytes,
        "gradient+rank wrote different database bytes across thread counts"
    );
    // Rank-objective records carry their provenance stamp.
    let text = String::from_utf8(serial_bytes).unwrap();
    assert!(text.contains("\"obj\":\"rank\""), "rank records missed the objective stamp");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn telemetry_never_changes_results_or_database_bytes() {
    // Telemetry is observation-only: attaching a trace sink (and the
    // always-on metrics counters it rides with) must leave the search
    // outcome and the committed on-disk database byte-identical, at any
    // thread count.
    use metaschedule::db::JsonFileDb;
    use metaschedule::telemetry::{validate_trace, TraceSink};

    let dir = std::env::temp_dir().join(format!("ms-telemetry-determinism-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let target = Target::cpu_avx512();
    let prog = workloads::matmul(1, 128, 128, 128);
    let run = |tag: &str, threads: usize, trace: bool| {
        let ctx = TuneContext::generic(target.clone());
        let trace_path = dir.join(format!("{tag}.trace.json"));
        let sink = if trace {
            let s = TraceSink::to_file(&trace_path).expect("open trace sink");
            ctx.set_trace_sink(std::sync::Arc::clone(&s));
            Some(s)
        } else {
            None
        };
        let db_path = dir.join(format!("{tag}.db.jsonl"));
        let mut db = JsonFileDb::open(&db_path).unwrap();
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        let res = EvolutionarySearch::new(cfg(32, threads)).tune_db(
            &prog,
            &ctx,
            &mut model,
            &mut measurer,
            &mut db,
            23,
        );
        if let Some(s) = sink {
            let events = s.finish().expect("flush trace");
            assert!(events > 0, "instrumented run emitted trace events");
            let text = std::fs::read_to_string(&trace_path).unwrap();
            validate_trace(&text).expect("trace is a valid Chrome trace");
        }
        drop(db);
        (res, std::fs::read(&db_path).unwrap())
    };

    let (base, base_bytes) = run("t1-off", 1, false);
    for (tag, threads, trace) in [("t4-off", 4, false), ("t1-on", 1, true), ("t4-on", 4, true)] {
        let (r, bytes) = run(tag, threads, trace);
        assert_eq!(base.best_latency_s, r.best_latency_s, "{tag} diverged");
        assert_eq!(base.curve, r.curve, "{tag} curve diverged");
        assert_eq!(
            trace_to_text(&base.best_trace),
            trace_to_text(&r.best_trace),
            "{tag} best trace diverged"
        );
        assert_eq!(base_bytes, bytes, "{tag} produced different database bytes");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn feature_cache_never_changes_results_or_database_bytes() {
    // The per-canonical-trace feature cache is a pure accelerator:
    // cached vectors are element-exact copies of fresh extractions, so
    // toggling the cache (the `--no-feature-cache` escape hatch) must
    // leave the search outcome and the committed on-disk database
    // byte-identical, at any thread count. The warm cold-run guarantee
    // is checked too: with the cache on, round-1 rescoring of round-0
    // measured elites must actually hit.
    use metaschedule::db::JsonFileDb;

    let dir = std::env::temp_dir().join(format!("ms-featcache-determinism-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let target = Target::cpu_avx512();
    let prog = workloads::matmul(1, 128, 128, 128);
    let run = |tag: &str, threads: usize, cache_on: bool| {
        let ctx = TuneContext::generic(target.clone());
        ctx.set_feature_cache_enabled(cache_on);
        let db_path = dir.join(format!("{tag}.db.jsonl"));
        let mut db = JsonFileDb::open(&db_path).unwrap();
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        let res = EvolutionarySearch::new(cfg(32, threads)).tune_db(
            &prog,
            &ctx,
            &mut model,
            &mut measurer,
            &mut db,
            29,
        );
        let hits = ctx.feature_cache().map(|c| c.hits()).unwrap_or(0);
        if cache_on {
            assert!(hits > 0, "{tag}: cache enabled but never hit");
        } else {
            assert!(ctx.feature_cache().is_none(), "{tag}: cache should be disabled");
        }
        drop(db);
        (res, std::fs::read(&db_path).unwrap())
    };

    let (base, base_bytes) = run("cache-on-t1", 1, true);
    for (tag, threads, cache_on) in [
        ("cache-on-t4", 4, true),
        ("cache-off-t1", 1, false),
        ("cache-off-t4", 4, false),
    ] {
        let (r, bytes) = run(tag, threads, cache_on);
        assert_eq!(base.best_latency_s, r.best_latency_s, "{tag} diverged");
        assert_eq!(base.curve, r.curve, "{tag} curve diverged");
        assert_eq!(
            trace_to_text(&base.best_trace),
            trace_to_text(&r.best_trace),
            "{tag} best trace diverged"
        );
        assert_eq!(base_bytes, bytes, "{tag} produced different database bytes");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fused_workload_search_identical_across_thread_counts() {
    // The determinism contract extends to graph-fused programs: tune a
    // real multi-member fused task from the BERT-base DAG (a dense
    // anchor with its absorbed epilogue chain) at 1 and 4 threads and
    // require byte-identical outcomes.
    let target = Target::cpu_avx512();
    let g = metaschedule::graph::bert_base_graph();
    let task = metaschedule::graph::extract_fused_tasks(&g)
        .into_iter()
        .find(|t| t.prog.name.starts_with("fused_"))
        .expect("bert-base fuses at least one multi-member group");
    let ctx = TuneContext::generic(target.clone());
    let run = |threads: usize| {
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        EvolutionarySearch::new(cfg(32, threads)).tune(
            &task.prog,
            &ctx,
            &mut model,
            &mut measurer,
            19,
        )
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(serial.best_latency_s, parallel.best_latency_s);
    assert_eq!(
        structural_hash(&serial.best_prog),
        structural_hash(&parallel.best_prog)
    );
    assert_eq!(
        trace_to_text(&serial.best_trace),
        trace_to_text(&parallel.best_trace)
    );
    assert_eq!(serial.curve, parallel.curve);
}

#[test]
fn repeated_runs_are_reproducible() {
    // Same seed, same thread count, run twice: byte-identical output (no
    // hidden global state, no time dependence).
    let target = Target::cpu_avx512();
    let prog = workloads::fused_dense(64, 128, 64);
    let ctx = TuneContext::generic(target.clone());
    let run = || {
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        EvolutionarySearch::new(cfg(32, 4)).tune(&prog, &ctx, &mut model, &mut measurer, 5)
    };
    let a = run();
    let b = run();
    assert_eq!(a.best_latency_s, b.best_latency_s);
    assert_eq!(a.curve, b.curve);
    assert_eq!(trace_to_text(&a.best_trace), trace_to_text(&b.best_trace));
}
