//! End-to-end persistence: tune into a JSONL file, re-open it in a
//! "new session", and verify the acceptance contract — the second run
//! starts from the first run's best, the cost model pretrains from
//! records, `db stats` numbers add up, and every stored trace
//! round-trips through `trace::serde`.

use std::path::{Path, PathBuf};

use metaschedule::cost_model::GbtCostModel;
use metaschedule::db::{pretrain_cost_model, Database, DbStats, JsonFileDb};
use metaschedule::search::{EvolutionarySearch, SearchConfig, SimMeasurer};
use metaschedule::sim::Target;
use metaschedule::space::SpaceComposer;
use metaschedule::tir::structural_hash;
use metaschedule::trace::serde::{text_to_trace, trace_to_text};
use metaschedule::workloads;

/// Unique temp path per test; removed on drop.
fn tmp(name: &str) -> (PathBuf, Guard) {
    let p = std::env::temp_dir().join(format!("ms-dbpersist-{}-{name}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&p);
    (p.clone(), Guard(p))
}

struct Guard(PathBuf);
impl Drop for Guard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn quick_cfg(trials: usize) -> SearchConfig {
    SearchConfig {
        population: 24,
        generations: 3,
        num_trials: trials,
        measure_batch: 8,
        ..SearchConfig::default()
    }
}

/// One "session": open the file, tune, drop the handle.
fn tune_session(path: &Path, trials: usize, seed: u64) -> metaschedule::search::TuneResult {
    let target = Target::cpu_avx512();
    let prog = workloads::matmul(1, 128, 128, 128);
    let composer = SpaceComposer::generic(target.clone());
    let mut db = JsonFileDb::open(path).expect("open db");
    let mut model = GbtCostModel::new();
    let mut measurer = SimMeasurer::new(target);
    EvolutionarySearch::new(quick_cfg(trials)).tune_db(&prog, &composer, &mut model, &mut measurer, &mut db, seed)
}

#[test]
fn second_session_resumes_from_first() {
    let (path, _g) = tmp("resume");
    let first = tune_session(&path, 24, 42);
    assert_eq!(first.warm_records, 0, "fresh file must start cold");

    // "New session": a separate open of the same file.
    let second = tune_session(&path, 24, 42);
    assert!(second.warm_records > 0, "second session did not warm-start");
    assert!(
        second.best_latency_s <= first.best_latency_s,
        "resumed run regressed: {} vs {}",
        second.best_latency_s,
        first.best_latency_s
    );

    // The file accumulated both sessions, with no candidate measured twice.
    let db = JsonFileDb::open(&path).unwrap();
    let stats = DbStats::compute(&db);
    assert_eq!(stats.workloads.len(), 1);
    assert_eq!(stats.records, first.trials + second.trials);
    let hashes = db.candidate_hashes(0);
    let unique: std::collections::HashSet<u64> = hashes.iter().copied().collect();
    assert_eq!(unique.len(), hashes.len(), "a candidate was re-measured across sessions");
}

#[test]
fn stored_traces_roundtrip_and_replay_to_recorded_best() {
    let (path, _g) = tmp("roundtrip");
    let result = tune_session(&path, 24, 7);
    let db = JsonFileDb::open(&path).unwrap();
    let top = db.query_top_k(0, 3);
    assert!(!top.is_empty());
    // Acceptance: the top record's trace round-trips through trace::serde
    // and replays to a program matching its stored candidate hash.
    for rec in &top {
        let text = trace_to_text(&rec.trace);
        assert_eq!(text_to_trace(&text).unwrap(), rec.trace);
        let prog = workloads::matmul(1, 128, 128, 128);
        let sch = metaschedule::trace::replay(&rec.trace, &prog, 0).expect("stored trace must replay");
        assert_eq!(structural_hash(&sch.prog), rec.cand_hash);
    }
    // The best record matches the returned tuning result.
    assert_eq!(top[0].best_latency(), Some(result.best_latency_s));
    assert_eq!(trace_to_text(&top[0].trace), trace_to_text(&result.best_trace));
}

#[test]
fn pretraining_from_file_fits_the_model() {
    let (path, _g) = tmp("pretrain");
    tune_session(&path, 24, 5);
    let db = JsonFileDb::open(&path).unwrap();
    let prog = workloads::matmul(1, 128, 128, 128);
    let mut model = GbtCostModel::new();
    assert_eq!(model.n_samples(), 0);
    let fed = pretrain_cost_model(&mut model, &db, 0, &prog, 256);
    assert!(fed > 0);
    assert_eq!(model.n_samples(), fed);
    assert!(model.predict(&[&prog])[0] != 0.0, "model still cold after file pretrain");
}

#[test]
fn distinct_targets_do_not_share_records() {
    let (path, _g) = tmp("targets");
    let prog = workloads::matmul(1, 128, 128, 128);
    let tune_on = |path: &Path, target: Target, seed: u64| {
        let composer = SpaceComposer::generic(target.clone());
        let mut db = JsonFileDb::open(path).expect("open db");
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target);
        EvolutionarySearch::new(quick_cfg(16)).tune_db(&prog, &composer, &mut model, &mut measurer, &mut db, seed)
    };
    let cpu = tune_on(&path, Target::cpu_avx512(), 1);
    // Same program on GPU: the cpu records must not leak into its warm set.
    let gpu = tune_on(&path, Target::gpu(), 1);
    assert_eq!(cpu.warm_records, 0);
    assert_eq!(gpu.warm_records, 0, "gpu run warm-started from cpu records");
    let db = JsonFileDb::open(&path).unwrap();
    assert_eq!(db.workload_entries().len(), 2, "one workload per (program, target)");
    // But a second cpu run does warm-start.
    let cpu2 = tune_on(&path, Target::cpu_avx512(), 2);
    assert!(cpu2.warm_records > 0);
}
