//! End-to-end persistence: tune into a JSONL file, re-open it in a
//! "new session", and verify the acceptance contract — the second run
//! starts from the first run's best, the cost model pretrains from
//! records, `db stats` numbers add up, and every stored trace
//! round-trips through `trace::serde`.

use std::path::{Path, PathBuf};

use metaschedule::cost_model::GbtCostModel;
use metaschedule::ctx::TuneContext;
use metaschedule::db::{pretrain_cost_model, Database, DbStats, JsonFileDb};
use metaschedule::search::{EvolutionarySearch, SearchConfig, SimMeasurer};
use metaschedule::sim::Target;
use metaschedule::tir::structural_hash;
use metaschedule::trace::serde::{text_to_trace, trace_to_text};
use metaschedule::workloads;

/// Unique temp path per test; removed on drop.
fn tmp(name: &str) -> (PathBuf, Guard) {
    let p = std::env::temp_dir().join(format!("ms-dbpersist-{}-{name}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&p);
    (p.clone(), Guard(p))
}

struct Guard(PathBuf);
impl Drop for Guard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn quick_cfg(trials: usize) -> SearchConfig {
    SearchConfig {
        population: 24,
        generations: 3,
        num_trials: trials,
        measure_batch: 8,
        ..SearchConfig::default()
    }
}

/// One "session": open the file, tune, drop the handle.
fn tune_session(path: &Path, trials: usize, seed: u64) -> metaschedule::search::TuneResult {
    let target = Target::cpu_avx512();
    let prog = workloads::matmul(1, 128, 128, 128);
    let ctx = TuneContext::generic(target.clone());
    let mut db = JsonFileDb::open(path).expect("open db");
    let mut model = GbtCostModel::new();
    let mut measurer = SimMeasurer::new(target);
    EvolutionarySearch::new(quick_cfg(trials)).tune_db(&prog, &ctx, &mut model, &mut measurer, &mut db, seed)
}

#[test]
fn second_session_resumes_from_first() {
    let (path, _g) = tmp("resume");
    let first = tune_session(&path, 24, 42);
    assert_eq!(first.warm_records, 0, "fresh file must start cold");

    // "New session": a separate open of the same file.
    let second = tune_session(&path, 24, 42);
    assert!(second.warm_records > 0, "second session did not warm-start");
    assert!(
        second.best_latency_s <= first.best_latency_s,
        "resumed run regressed: {} vs {}",
        second.best_latency_s,
        first.best_latency_s
    );

    // The file accumulated both sessions, with no candidate measured twice.
    let db = JsonFileDb::open(&path).unwrap();
    let stats = DbStats::compute(&db);
    assert_eq!(stats.workloads.len(), 1);
    assert_eq!(stats.records, first.trials + second.trials);
    let hashes = db.candidate_hashes(0);
    let unique: std::collections::HashSet<u64> = hashes.iter().copied().collect();
    assert_eq!(unique.len(), hashes.len(), "a candidate was re-measured across sessions");
}

#[test]
fn stored_traces_roundtrip_and_replay_to_recorded_best() {
    let (path, _g) = tmp("roundtrip");
    let result = tune_session(&path, 24, 7);
    let db = JsonFileDb::open(&path).unwrap();
    let top = db.query_top_k(0, 3);
    assert!(!top.is_empty());
    // Acceptance: the top record's trace round-trips through trace::serde
    // and replays to a program matching its stored candidate hash.
    for rec in &top {
        let text = trace_to_text(&rec.trace);
        assert_eq!(text_to_trace(&text).unwrap(), rec.trace);
        let prog = workloads::matmul(1, 128, 128, 128);
        let sch = metaschedule::trace::replay(&rec.trace, &prog, 0).expect("stored trace must replay");
        assert_eq!(structural_hash(&sch.prog), rec.cand_hash);
    }
    // The best record matches the returned tuning result.
    assert_eq!(top[0].best_latency(), Some(result.best_latency_s));
    assert_eq!(trace_to_text(&top[0].trace), trace_to_text(&result.best_trace));
}

#[test]
fn pretraining_from_file_fits_the_model() {
    let (path, _g) = tmp("pretrain");
    tune_session(&path, 24, 5);
    let db = JsonFileDb::open(&path).unwrap();
    let prog = workloads::matmul(1, 128, 128, 128);
    let mut model = GbtCostModel::new();
    assert_eq!(model.n_samples(), 0);
    let stats = pretrain_cost_model(&mut model, &db, 0, &prog, 256);
    assert!(stats.fed > 0);
    assert_eq!(stats.stale_skipped, 0, "fresh records must all be sim-compatible");
    assert_eq!(model.n_samples(), stats.fed);
    assert!(model.predict(&[&prog])[0] != 0.0, "model still cold after file pretrain");
}

#[test]
fn truncated_final_line_recovers_every_intact_record() {
    // Simulated crash mid-append: the file ends in half a record line.
    let (path, _g) = tmp("truncate");
    let first = tune_session(&path, 16, 13);
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.ends_with('\n'));
    // Chop into the final line (drop its newline + tail bytes).
    std::fs::write(&path, &text[..text.len() - 25]).unwrap();

    let db = JsonFileDb::open(&path).expect("recovery open");
    assert_eq!(db.skipped_lines(), 1, "exactly the torn line is skipped");
    assert_eq!(db.num_records(), first.trials - 1, "every intact record recovered");
    assert!(db.best_latency(0).is_some());
    drop(db);

    // The recovered file is still a working database: a new session
    // warm-starts from it and appends cleanly past the torn tail.
    let resumed = tune_session(&path, 8, 13);
    assert!(resumed.warm_records > 0, "recovery lost the warm-start set");
    assert!(resumed.best_latency_s.is_finite());
    let reopened = JsonFileDb::open(&path).unwrap();
    assert_eq!(
        reopened.num_records(),
        first.trials - 1 + resumed.trials,
        "appends after recovery must all be parseable"
    );
}

#[test]
fn interleaved_garbage_lines_recover_and_report_skip_count() {
    let (path, _g) = tmp("garbage");
    let first = tune_session(&path, 16, 17);
    // Sprinkle garbage between intact lines (editor droppings, partial
    // writes from another process, a JSON object of the wrong kind).
    let lines: Vec<String> = std::fs::read_to_string(&path)
        .unwrap()
        .lines()
        .map(String::from)
        .collect();
    let mut vandalized = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        vandalized.push(line.clone());
        if i % 5 == 0 {
            vandalized.push("%% not json at all".to_string());
        }
        if i == 2 {
            vandalized.push("{\"kind\":\"frobnicate\"}".to_string());
        }
    }
    let n_garbage = vandalized.len() - lines.len();
    std::fs::write(&path, vandalized.join("\n") + "\n").unwrap();

    let db = JsonFileDb::open(&path).expect("recovery open");
    assert_eq!(db.skipped_lines(), n_garbage);
    assert!(!db.skip_notes().is_empty(), "skip diagnostics must name lines");
    assert_eq!(db.num_records(), first.trials, "garbage must not cost intact records");
    let stats = DbStats::compute(&db);
    assert_eq!(stats.records, first.trials);
    assert_eq!(db.best_latency(0), Some(first.best_latency_s));
    drop(db);

    // Dropping the corrupt bytes for good is gated: refused without
    // `repair`, performed (and reported) with it.
    let policy = metaschedule::db::CompactionPolicy::default();
    let refused = metaschedule::db::compact_file(&path, &policy, false).unwrap_err();
    assert!(refused.contains("--repair"), "{refused}");
    assert_eq!(
        JsonFileDb::open(&path).unwrap().skipped_lines(),
        n_garbage,
        "refused compaction must leave the file untouched"
    );
    let report = metaschedule::db::compact_file(&path, &policy, true).unwrap();
    assert_eq!(report.corrupt_dropped, n_garbage);
    let repaired = JsonFileDb::open(&path).unwrap();
    assert_eq!(repaired.skipped_lines(), 0);
    assert_eq!(repaired.best_latency(0), Some(first.best_latency_s));
}

#[test]
fn distinct_targets_do_not_share_records() {
    let (path, _g) = tmp("targets");
    let prog = workloads::matmul(1, 128, 128, 128);
    let tune_on = |path: &Path, target: Target, seed: u64| {
        let ctx = TuneContext::generic(target.clone());
        let mut db = JsonFileDb::open(path).expect("open db");
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target);
        EvolutionarySearch::new(quick_cfg(16)).tune_db(&prog, &ctx, &mut model, &mut measurer, &mut db, seed)
    };
    let cpu = tune_on(&path, Target::cpu_avx512(), 1);
    // Same program on GPU: the cpu records must not leak into its warm set.
    let gpu = tune_on(&path, Target::gpu(), 1);
    assert_eq!(cpu.warm_records, 0);
    assert_eq!(gpu.warm_records, 0, "gpu run warm-started from cpu records");
    let db = JsonFileDb::open(&path).unwrap();
    assert_eq!(db.workload_entries().len(), 2, "one workload per (program, target)");
    // But a second cpu run does warm-start.
    let cpu2 = tune_on(&path, Target::cpu_avx512(), 2);
    assert!(cpu2.warm_records > 0);
}
