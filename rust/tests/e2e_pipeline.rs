//! Cross-module integration tests: space -> search -> cost model ->
//! simulator -> graph pipeline, plus trace serialization round trips and
//! failure injection (a measurer that rejects everything must not wedge
//! the search).

use metaschedule::baselines::{Ansor, AutoTvm};
use metaschedule::cost_model::GbtCostModel;
use metaschedule::ctx::TuneContext;
use metaschedule::exp::Report;
use metaschedule::graph::{self, extract_tasks};
use metaschedule::search::{
    EvolutionarySearch, Measurer, SearchConfig, SimMeasurer, TaskScheduler,
};
use metaschedule::sim::{simulate, Target};
use metaschedule::tir::{structural_hash, Program};
use metaschedule::trace::replay;
use metaschedule::trace::serde::{text_to_trace, trace_to_text};
use metaschedule::workloads;

fn quick_cfg(trials: usize) -> SearchConfig {
    SearchConfig {
        population: 24,
        generations: 3,
        num_trials: trials,
        measure_batch: 8,
        ..SearchConfig::default()
    }
}

#[test]
fn full_pipeline_all_suite_workloads_cpu() {
    // Every A.2 workload must tune end-to-end and improve over naive.
    let target = Target::cpu_avx512();
    let ctx = TuneContext::generic(target.clone());
    for w in workloads::suite() {
        let prog = (w.build)();
        let naive = simulate(&prog, &target).unwrap().total_s;
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        let r = EvolutionarySearch::new(quick_cfg(24)).tune(
            &prog,
            &ctx,
            &mut model,
            &mut measurer,
            9,
        );
        assert!(
            r.best_latency_s <= naive,
            "{}: tuned {} worse than naive {naive}",
            w.name,
            r.best_latency_s
        );
        r.best_prog.check_integrity().unwrap();
    }
}

#[test]
fn full_pipeline_gpu_suite_subset() {
    let target = Target::gpu();
    let ctx = TuneContext::generic(target.clone());
    for name in ["GMM", "C2D", "SFM", "TBG"] {
        let w = workloads::by_name(name).unwrap();
        let prog = (w.build)();
        let naive = simulate(&prog, &target).unwrap().total_s;
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        let r = EvolutionarySearch::new(quick_cfg(24)).tune(
            &prog,
            &ctx,
            &mut model,
            &mut measurer,
            11,
        );
        assert!(
            r.best_latency_s < naive,
            "{name}: tuned {} vs naive {naive}",
            r.best_latency_s
        );
    }
}

#[test]
fn best_trace_serializes_and_replays_everywhere() {
    // Search result traces must round-trip through the text format and
    // replay to the identical program — the artifact a user would save.
    let target = Target::cpu_avx512();
    let ctx = TuneContext::generic(target.clone());
    let prog = workloads::fused_dense(64, 256, 128);
    let mut model = GbtCostModel::new();
    let mut measurer = SimMeasurer::new(target.clone());
    let r = EvolutionarySearch::new(quick_cfg(16)).tune(
        &prog,
        &ctx,
        &mut model,
        &mut measurer,
        3,
    );
    let text = trace_to_text(&r.best_trace);
    let back = text_to_trace(&text).unwrap();
    let replayed = replay(&back, &prog, 0).unwrap();
    assert_eq!(
        structural_hash(&replayed.prog),
        structural_hash(&r.best_prog)
    );
}

struct RejectingMeasurer(usize);

impl Measurer for RejectingMeasurer {
    fn measure(&mut self, _prog: &Program) -> Option<f64> {
        self.0 += 1;
        None
    }
    fn count(&self) -> usize {
        self.0
    }
    fn target_name(&self) -> String {
        "rejecting".to_string()
    }
}

#[test]
#[should_panic(expected = "no valid schedule found")]
fn all_rejected_measurements_fail_cleanly() {
    // Failure injection: if the hardware rejects everything the search
    // must terminate with a clear panic, not loop forever.
    let target = Target::cpu_avx512();
    let ctx = TuneContext::generic(target.clone());
    let prog = workloads::matmul(1, 64, 64, 64);
    let mut model = GbtCostModel::new();
    let mut measurer = RejectingMeasurer(0);
    let _ = EvolutionarySearch::new(quick_cfg(16)).tune(
        &prog,
        &ctx,
        &mut model,
        &mut measurer,
        1,
    );
}

#[test]
fn baselines_and_metaschedule_rank_sanely_on_gmm() {
    // The Figure 8 ordering on one workload: MetaSchedule <= best(TVM)
    // within noise, and every tuner beats naive.
    let target = Target::cpu_avx512();
    let prog = workloads::matmul(1, 128, 128, 128);
    let naive = simulate(&prog, &target).unwrap().total_s;
    let trials = 48;

    let mut m = SimMeasurer::new(target.clone());
    let autotvm = AutoTvm { num_trials: trials }
        .tune(&prog, &target, &mut m, 1)
        .best_latency_s;
    let mut m = SimMeasurer::new(target.clone());
    let ansor = Ansor { num_trials: trials, threads: 0 }
        .tune(&prog, &target, &mut m, 1)
        .best_latency_s;
    let ctx = TuneContext::generic(target.clone());
    // Same search hyperparameters as the Ansor baseline, so the comparison
    // isolates search-space construction; best-of-3 seeds damps the noise
    // of this deliberately tiny trial budget.
    let ms = (1..=3)
        .map(|seed| {
            let mut model = GbtCostModel::new();
            let mut m = SimMeasurer::new(target.clone());
            EvolutionarySearch::new(SearchConfig {
                num_trials: trials,
                ..SearchConfig::default()
            })
            .tune(&prog, &ctx, &mut model, &mut m, seed)
            .best_latency_s
        })
        .fold(f64::INFINITY, f64::min);

    assert!(autotvm < naive && ansor < naive && ms < naive);
    let tvm_best = autotvm.min(ansor);
    assert!(
        ms <= tvm_best * 1.2,
        "MetaSchedule {ms} should be similar-or-better than TVM {tvm_best}"
    );
}

#[test]
fn bert_base_task_scheduler_end_to_end() {
    let target = Target::cpu_avx512();
    let ops = graph::by_name("bert-base").unwrap();
    let tasks = extract_tasks(&ops);
    assert_eq!(tasks.len(), 8);
    let ctx = TuneContext::generic(target.clone());
    let mut measurer = SimMeasurer::new(target.clone());
    let ts = TaskScheduler::new(quick_cfg(16));
    let results = ts.tune_tasks(&tasks, &ctx, &mut measurer, 16 * tasks.len(), 5);
    let e2e = TaskScheduler::e2e_latency(&tasks, &results);
    let naive: f64 = tasks
        .iter()
        .map(|t| simulate(&t.prog, &target).unwrap().total_s * t.weight as f64)
        .sum();
    assert!(e2e < naive / 10.0, "e2e {e2e} vs naive {naive}");
}

#[test]
fn report_writer_appends_jsonl() {
    let mut r = Report::new("itest", "integration");
    r.push("W", "S", 1e-3);
    let path = std::env::temp_dir().join("ms_report_test.jsonl");
    let path_s = path.to_str().unwrap();
    let _ = std::fs::remove_file(&path);
    r.write(path_s).unwrap();
    r.write(path_s).unwrap();
    let content = std::fs::read_to_string(&path).unwrap();
    assert_eq!(content.lines().count(), 2);
    assert!(content.contains("\"itest\""));
    let _ = std::fs::remove_file(&path);
}
