//! Serving-layer integration: read-only snapshot loads, hit/miss
//! batch serving over a compacted file, auto-GC under a live tuning
//! session, and the concurrency contract — N readers hammering an
//! immutable snapshot while a writer commits, GCs, and publishes must
//! only ever observe whole snapshots (pre- or post-publish, never torn)
//! with thread-count-invariant lookup results.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use metaschedule::cost_model::GbtCostModel;
use metaschedule::ctx::TuneContext;
use metaschedule::db::{AutoGc, CompactionPolicy, Database, JsonFileDb, TuningRecord};
use metaschedule::search::{EvolutionarySearch, SearchConfig, SimMeasurer};
use metaschedule::serve::{serve_batch, serve_watch, DbWatcher, ServeConfig, ServingCache, SnapshotSlot};
use metaschedule::sim::Target;
use metaschedule::tir::structural_hash;
use metaschedule::trace::{Inst, Trace};
use metaschedule::workloads;

/// Unique temp path per test; removed on drop.
fn tmp(name: &str) -> (PathBuf, Guard) {
    let p = std::env::temp_dir().join(format!("ms-serving-{}-{name}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&p);
    (p.clone(), Guard(p))
}

struct Guard(PathBuf);
impl Drop for Guard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn quick_cfg(trials: usize) -> SearchConfig {
    SearchConfig {
        population: 24,
        generations: 3,
        num_trials: trials,
        measure_batch: 8,
        ..SearchConfig::default()
    }
}

/// One tuning "session" against the GMM workload (registered under its
/// display name, like the `tune` CLI does).
fn tune_gmm(path: &Path, trials: usize, seed: u64, auto_gc: Option<AutoGc>) -> (f64, usize) {
    let target = Target::cpu_avx512();
    let w = workloads::by_name("GMM").unwrap();
    let prog = (w.build)();
    let ctx = TuneContext::generic(target.clone());
    let mut db = JsonFileDb::open(path).expect("open db");
    db.set_auto_gc(auto_gc);
    db.register_workload(w.name, structural_hash(&prog), target.name);
    let mut model = GbtCostModel::new();
    let mut measurer = SimMeasurer::new(target);
    let r = EvolutionarySearch::new(quick_cfg(trials))
        .tune_db(&prog, &ctx, &mut model, &mut measurer, &mut db, seed);
    (r.best_latency_s, r.warm_records)
}

#[test]
fn read_only_snapshot_answers_like_the_live_db_without_touching_the_file() {
    let (path, _g) = tmp("load");
    let (best, _) = tune_gmm(&path, 24, 7, None);
    let bytes_before = std::fs::read(&path).unwrap();

    let (cache, skipped) = ServingCache::load(&path, 8).expect("load snapshot");
    assert_eq!(skipped, 0);
    assert_eq!(cache.num_workloads(), 1);
    let target = Target::cpu_avx512();
    let prog = (workloads::by_name("GMM").unwrap().build)();
    let shash = structural_hash(&prog);
    // Snapshot answers match the database's own top-k view.
    let db = JsonFileDb::open(&path).unwrap();
    assert_eq!(cache.lookup(shash, target.name), db.query_top_k(0, 1).first());
    assert_eq!(cache.best_latency(shash, target.name), Some(best));
    // apply_best reconstructs the recorded best program.
    let sch = cache.apply_best(&prog, target.name).expect("best trace replays");
    assert_eq!(structural_hash(&sch.prog), cache.lookup(shash, target.name).unwrap().cand_hash);
    // Loading was genuinely read-only.
    assert_eq!(std::fs::read(&path).unwrap(), bytes_before, "load must not modify the file");
    // Unknown hash / wrong target are clean misses.
    assert_eq!(cache.lookup(shash ^ 1, target.name), None);
    assert_eq!(cache.lookup(shash, "gpu"), None);
}

#[test]
fn serve_batch_hits_identically_before_and_after_compaction() {
    let (path, _g) = tmp("compact-serve");
    let (best, _) = tune_gmm(&path, 24, 11, None);
    let target = Target::cpu_avx512();
    let report_only = ServeConfig { miss_trials: 0, ..ServeConfig::default() };
    let serve_once = |path: &Path| {
        let mut db = JsonFileDb::open(path).unwrap();
        serve_batch(&["GMM".to_string()], &target, &mut db, &report_only).unwrap()
    };
    let pre = serve_once(&path);
    assert!(pre[0].hit);
    assert_eq!(pre[0].latency_s, Some(best));

    let report =
        metaschedule::db::compact_file(&path, &CompactionPolicy::keep_top(4), false).expect("compact");
    assert!(report.kept <= 4 + report.kept_failures);
    let post = serve_once(&path);
    assert!(post[0].hit, "compaction must not lose the served best");
    assert_eq!(post[0].latency_s, Some(best));
    assert!(post[0].records <= 4, "snapshot top-k exceeds the compaction policy");
}

#[test]
fn tuning_with_auto_gc_stays_resumable() {
    let (path, _g) = tmp("autogc-resume");
    let gc = || {
        Some(AutoGc {
            max_bytes: 4096,
            policy: CompactionPolicy::keep_top(8),
        })
    };
    let (first_best, warm0) = tune_gmm(&path, 24, 5, gc());
    assert_eq!(warm0, 0);
    let size_after_first = std::fs::metadata(&path).unwrap().len();
    let (second_best, warm1) = tune_gmm(&path, 24, 5, gc());
    assert!(warm1 > 0, "GC must not erase the warm-start set");
    assert!(second_best <= first_best, "resume regressed: {second_best} vs {first_best}");
    // The file stayed bounded instead of doubling.
    let size_after_second = std::fs::metadata(&path).unwrap().len();
    assert!(
        size_after_second < size_after_first * 3,
        "auto-GC never engaged: {size_after_first} -> {size_after_second} bytes"
    );
}

#[test]
fn watcher_fires_on_append_and_watch_mode_reserves() {
    let (path, _g) = tmp("watch");
    let (first_best, _) = tune_gmm(&path, 16, 3, None);

    // The watcher itself: quiet file -> no change; any append -> change.
    let mut watcher = DbWatcher::new(&path);
    assert!(!watcher.changed(), "no write, no change signal");
    let target = Target::cpu_avx512();
    let names = vec!["GMM".to_string()];

    // Watch mode end-to-end: a concurrent tuning session appends to the
    // file; serve_watch must notice, reload the snapshot, and re-serve.
    // The writer synchronizes on the initial-serve flag rather than a
    // sleep: serve_watch baselines its watcher BEFORE the round-0 serve,
    // so an append that happens after round 0 is guaranteed to be a
    // change the watcher sees — no lost race, no hang, on any scheduler.
    let rounds = std::sync::Mutex::new(Vec::new());
    let served_round0 = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|s| {
        let path2 = path.clone();
        let served_round0 = &served_round0;
        s.spawn(move || {
            while !served_round0.load(std::sync::atomic::Ordering::Acquire) {
                std::thread::yield_now();
            }
            // A concurrent writer appends to the file — here a workload
            // registration for another target (one guaranteed line, no
            // effect on the cpu serving answer), which is exactly the
            // signature change a tuner's commits would produce.
            let prog = (workloads::by_name("GMM").unwrap().build)();
            let mut db = JsonFileDb::open(&path2).unwrap();
            db.register_workload("GMM", structural_hash(&prog), "gpu");
            assert_eq!(db.commit_counter(), 1, "exactly one line appended");
        });
        let refreshes = serve_watch(
            &names,
            &target,
            path.to_str().unwrap(),
            8,
            10,
            Some(1),
            &mut |round, outcomes| {
                assert_eq!(outcomes.len(), 1);
                rounds.lock().unwrap().push((round, outcomes[0].hit, outcomes[0].latency_s));
                if round == 0 {
                    served_round0.store(true, std::sync::atomic::Ordering::Release);
                }
            },
        )
        .expect("watch serve");
        assert_eq!(refreshes, 1);
    });
    let observed = rounds.into_inner().unwrap();
    assert_eq!(observed.len(), 2, "initial serve + one refresh");
    assert_eq!(observed[0].0, 0);
    assert_eq!(observed[1].0, 1);
    assert!(observed[0].1 && observed[1].1, "both serves must hit");
    assert_eq!(observed[0].2, Some(first_best));
    // The refreshed snapshot's best can only match or improve (min over a
    // superset of records).
    assert!(observed[1].2.unwrap() <= first_best);
    // And the watcher saw the append too.
    assert!(watcher.changed(), "appends must flip the signature");
}

/// Synthetic record for the concurrency test (distinct cand hashes keep
/// the dedup index honest).
fn rec(workload: usize, cand: u64, lat: f64) -> TuningRecord {
    TuningRecord {
        workload,
        trace: Trace {
            insts: vec![Inst::GetBlock { name: "blk".into(), out: 0 }],
        },
        latencies: vec![lat],
        target: "cpu".into(),
        seed: 0,
        round: cand,
        cand_hash: cand,
        sim_version: "simtest".into(),
        rule_set: String::new(),
        objective: String::new(),
    }
}

#[test]
fn readers_observe_whole_snapshots_while_writer_commits_and_gcs() {
    const READERS: usize = 8;
    let (path, _g) = tmp("torn");
    let mut db = JsonFileDb::open(&path).unwrap();
    let a = db.register_workload("A", 0xa, "cpu");
    let b = db.register_workload("B", 0xb, "cpu");
    // Invariant made for tearing-detection: every commit writes B at
    // exactly twice A's latency, so EVERY consistent snapshot satisfies
    // best_B == 2 * best_A — while a torn mix of two published
    // snapshots (which all have distinct bests) violates it.
    db.commit_record(rec(a, 1, 4.0));
    db.commit_record(rec(b, 2, 8.0));
    // Final state after the writer's 200 improving commit pairs.
    let post = (Some(1.0), Some(2.0));

    let slot = Arc::new(SnapshotSlot::new(ServingCache::build(&db, 8)));
    db.set_auto_gc(Some(AutoGc {
        max_bytes: 2048,
        policy: CompactionPolicy::keep_top(4),
    }));

    fn observe(cache: &ServingCache) -> (Option<f64>, Option<f64>) {
        (cache.best_latency(0xa, "cpu"), cache.best_latency(0xb, "cpu"))
    }
    let published = std::sync::atomic::AtomicBool::new(false);
    let final_pairs: Vec<(Option<f64>, Option<f64>)> = std::thread::scope(|s| {
        let writer = {
            let slot = slot.clone();
            let published = &published;
            s.spawn(move || {
                // Interleave commits across workloads, improving towards
                // the post state; the byte budget forces several GC
                // passes mid-stream, and a fresh snapshot is published
                // every 20 commit pairs (including mid-GC states) so the
                // readers race against real swaps, not just one.
                for i in 0..200u64 {
                    let lat = 4.0 - 3.0 * (i as f64 + 1.0) / 200.0; // 4.0 -> 1.0
                    db.commit_record(rec(a, 100 + i, lat));
                    db.commit_record(rec(b, 10_000 + i, 2.0 * lat));
                    if (i + 1) % 20 == 0 {
                        slot.publish(ServingCache::build(&db, 8));
                    }
                }
                assert!(db.num_records() <= 32, "auto-GC never engaged");
                published.store(true, std::sync::atomic::Ordering::Release);
            })
        };
        let readers: Vec<_> = (0..READERS)
            .map(|_| {
                let slot = slot.clone();
                let published = &published;
                s.spawn(move || {
                    // Hammer lookups until the final snapshot lands.
                    // Every observation must be internally consistent
                    // (the B == 2A invariant every published snapshot
                    // satisfies and torn mixes violate) and bests may
                    // only improve across observations.
                    let mut last_a = f64::INFINITY;
                    loop {
                        let done = published.load(std::sync::atomic::Ordering::Acquire);
                        let pair = observe(&slot.get());
                        let (Some(la), Some(lb)) = pair else {
                            panic!("snapshot lost a workload: {pair:?}");
                        };
                        assert!(lb == 2.0 * la, "torn snapshot observed: {pair:?}");
                        assert!(la <= last_a, "snapshot went backwards: {la} after {last_a}");
                        last_a = la;
                        if pair == post {
                            return pair;
                        }
                        // The final publish happened-before we loaded
                        // `done`, so a get() after that must see it.
                        assert!(!done, "stale snapshot read after final publish");
                        std::thread::yield_now();
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        readers.into_iter().map(|r| r.join().unwrap()).collect()
    });
    // Thread-count invariance: every reader converged on the identical
    // result, and a fresh single-threaded lookup agrees.
    assert_eq!(final_pairs.len(), READERS);
    for pair in &final_pairs {
        assert_eq!(*pair, post);
    }
    let solo = ServingCache::load(&path, 8).unwrap().0;
    assert_eq!(observe(&solo), post, "on-disk state diverged from the published snapshot");
}
