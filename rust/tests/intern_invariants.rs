//! Invariants of the hash-consed trace IR (`trace::intern`) and the
//! per-canonical-trace feature cache built on it:
//!
//! 1. Interning round-trips every `Inst` variant byte-for-byte through
//!    the canonical `trace::serde` text form.
//! 2. Structurally equal traces get identical id chains — including
//!    across "sessions" (independent arenas), since assignment is a pure
//!    function of intern order.
//! 3. The memoized sampling-index list always equals a fresh
//!    `Trace::sampling_indices` scan (the list the mutators consume).
//! 4. The single-node rewrite (`intern_mutated`, via
//!    `TuneContext::mutate_interned`) equals a full re-intern.
//! 5. The feature cache never changes `extract_batch` output: cached and
//!    uncached vectors are element-exact equal.

use metaschedule::cost_model::{extract_batch, FeatKey, FeatureCache};
use metaschedule::ctx::TuneContext;
use metaschedule::sim::Target;
use metaschedule::telemetry::Metrics;
use metaschedule::tir::structural_hash;
use metaschedule::trace::serde::{inst_to_line, text_to_trace, trace_to_text};
use metaschedule::trace::{FactorArg, Inst, InternArena, Trace};
use metaschedule::util::prop::{check, PropConfig};
use metaschedule::util::rng::Rng;
use metaschedule::workloads;

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        ..PropConfig::default()
    }
}

/// One instance of every `Inst` variant (31 as of this writing — the
/// count assertion below fails if a new variant is added without
/// extending this list).
fn every_variant() -> Vec<Inst> {
    vec![
        Inst::GetBlock { name: "matmul".into(), out: 0 },
        Inst::GetLoops { block: 0, outs: vec![1, 2, 3] },
        Inst::GetProducers { block: 0, outs: vec![4] },
        Inst::GetConsumers { block: 0, outs: vec![5] },
        Inst::SamplePerfectTile {
            loop_rv: 1,
            n: 2,
            max_innermost: 16,
            outs: vec![6, 7],
            decision: vec![8, 16],
        },
        Inst::SampleCategorical {
            candidates: vec![0, 16, 64],
            probs: vec![0.25, 0.5, 0.25],
            out: 8,
            decision: 1,
        },
        Inst::SampleComputeLocation { block: 0, out: 9, decision: -1 },
        Inst::Split {
            loop_rv: 1,
            factors: vec![FactorArg::Rv(6), FactorArg::Lit(4)],
            outs: vec![10, 11],
        },
        Inst::Fuse { loops: vec![10, 11], out: 12 },
        Inst::Reorder { loops: vec![12, 2] },
        Inst::Parallel { loop_rv: 12 },
        Inst::Vectorize { loop_rv: 2 },
        Inst::Unroll { loop_rv: 3 },
        Inst::Bind { loop_rv: 2, thread: "threadIdx.x".into() },
        Inst::AddUnitLoop { block: 0, out: 13 },
        Inst::CacheRead { block: 0, read_idx: 0, scope: "shared".into(), out: 14 },
        Inst::CacheWrite { block: 0, write_idx: 0, scope: "local".into(), out: 15 },
        Inst::SetScope { block: 0, write_idx: 0, scope: "global".into() },
        Inst::StorageAlign { block: 0, write_idx: 0, axis: 1, factor: 32 },
        Inst::ComputeAt { block: 0, loop_rv: 2 },
        Inst::ReverseComputeAt { block: 0, loop_rv: 2 },
        Inst::ComputeInline { block: 0 },
        Inst::ReverseComputeInline { block: 0 },
        Inst::RFactor { block: 0, loop_rv: 3, out: 16 },
        Inst::DecomposeReduction { block: 0, loop_rv: 3, out: 17 },
        Inst::Blockize { loop_rv: 2, out: 18 },
        Inst::Tensorize { loop_rv: 2, intrin: "wmma-16x16x16".into(), out: 19 },
        Inst::AnnotateBlock {
            block: 0,
            key: "meta-schedule-tiling".into(),
            value: "SSRSRS".into(),
        },
        Inst::AnnotateLoop {
            loop_rv: 2,
            key: "auto-unroll-max-step".into(),
            value: "64".into(),
        },
        Inst::UnannotateBlock { block: 0, key: "meta-schedule-tiling".into() },
        Inst::EnterPostproc,
    ]
}

#[test]
fn interning_round_trips_every_inst_variant_byte_for_byte() {
    let insts = every_variant();
    // Completeness guard: one line per distinct opcode.
    let opcodes: std::collections::HashSet<&str> = insts.iter().map(|i| i.opcode()).collect();
    assert_eq!(opcodes.len(), 31, "every_variant() must cover all Inst variants");

    let trace = Trace { insts };
    let arena = InternArena::new();
    let interned = arena.intern(&trace);
    let back = arena.materialize(&interned);
    assert_eq!(back, trace);
    // Byte-for-byte through the canonical serde text (the same function
    // interning fingerprints with).
    for (orig, round) in trace.insts.iter().zip(back.insts.iter()) {
        assert_eq!(inst_to_line(orig), inst_to_line(round));
    }
    // And through the full text format: parse(text(trace)) interns to
    // the identical chain.
    let reparsed = text_to_trace(&trace_to_text(&trace)).expect("canonical text parses");
    assert_eq!(arena.intern(&reparsed), interned);
}

#[test]
fn equal_traces_intern_to_identical_chains_across_sessions() {
    // Two independent arenas fed the same real design spaces in the same
    // order assign the identical chains — what "canonical across
    // sessions" means. Inequality must also agree with trace inequality.
    let ctx = TuneContext::generic(Target::cpu_avx512());
    check(
        cfg(12),
        |rng| rng.next_u64(),
        |&seed| {
            let prog = workloads::matmul(1, 64, 64, 64);
            let designs = ctx.generate(&prog, seed);
            let a = InternArena::new();
            let b = InternArena::new();
            for (i, x) in designs.iter().enumerate() {
                let ia = a.intern(&x.trace);
                let ib = b.intern(&x.trace);
                if ia.ids() != ib.ids() {
                    return Err("fresh arenas disagree on a chain".into());
                }
                for y in designs.iter().skip(i + 1) {
                    let same_chain = ia == a.intern(&y.trace);
                    if same_chain != (x.trace == y.trace) {
                        return Err("chain equality diverges from trace equality".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sampling_memo_always_matches_a_fresh_scan() {
    let ctx = TuneContext::generic(Target::cpu_avx512());
    let arena = InternArena::new();
    check(
        cfg(10),
        |rng| rng.next_u64(),
        |&seed| {
            let prog = workloads::fused_dense(64, 128, 64);
            let mut rng = Rng::seed_from_u64(seed);
            for s in ctx.generate(&prog, seed) {
                let it = arena.intern(&s.trace);
                if it.sampling_indices() != s.trace.sampling_indices().as_slice() {
                    return Err("memoized sampling indices diverge".into());
                }
                // Mutated traces must keep the memo in sync too.
                if let Some(m) = ctx.mutate(&s.trace, &prog, &mut rng, seed) {
                    let im = arena.intern(&m.trace);
                    if im.sampling_indices() != m.trace.sampling_indices().as_slice() {
                        return Err("memo diverges after mutation".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn single_node_rewrite_equals_full_reintern() {
    // `mutate_interned` rewrites one decision node in place; the result
    // must be indistinguishable from interning the mutated trace from
    // scratch (ids AND materialized instructions), in release builds too.
    let ctx = TuneContext::generic(Target::cpu_avx512());
    let prog = workloads::matmul(1, 128, 128, 128);
    let mut rng = Rng::seed_from_u64(11);
    let mut rewrites = 0usize;
    for s in ctx.generate(&prog, 11) {
        let parent = ctx.intern_trace(&s.trace);
        for seed in 0..8u64 {
            if let Some((sch, child)) = ctx.mutate_interned(&parent, &s.trace, &prog, &mut rng, seed)
            {
                rewrites += 1;
                assert_eq!(child, ctx.intern_trace(&sch.trace));
                assert_eq!(ctx.arena().materialize(&child), sch.trace);
                assert_eq!(
                    child.sampling_indices(),
                    sch.trace.sampling_indices().as_slice()
                );
            }
        }
    }
    assert!(rewrites > 0, "design space produced no successful mutations");
}

#[test]
fn feature_cache_never_changes_extract_batch_output() {
    // Cold pass (all misses), warm pass (all hits): both element-exact
    // equal to the uncached batch extraction the search used to do.
    let ctx = TuneContext::generic(Target::cpu_avx512());
    let prog = workloads::fused_dense(64, 128, 64);
    let wl = structural_hash(&prog);
    let metrics = Metrics::new();
    let cache = FeatureCache::new(&metrics);
    let designs = ctx.generate(&prog, 7);
    let progs: Vec<&metaschedule::tir::Program> = designs.iter().map(|s| &s.prog).collect();
    let uncached = extract_batch(&progs);
    for pass in 0..2 {
        for (s, want) in designs.iter().zip(uncached.iter()) {
            let key = FeatKey { workload: wl, trace: ctx.intern_trace(&s.trace) };
            let got = cache.get_or_extract(&key, &s.prog);
            assert_eq!(got.as_ref(), want, "pass {pass}: cached vector differs");
        }
    }
    assert_eq!(cache.misses(), designs.len() as u64);
    assert_eq!(cache.hits(), designs.len() as u64);
}
