//! Property-based tests over the coordinator invariants (in-tree
//! mini-proptest, `util::prop`): random schedule transformations must
//! preserve program semantics proxies (flop count, structural integrity),
//! traces must replay deterministically, mutation must stay on-support,
//! and the simulator must be deterministic and monotone where physics
//! says so.

use metaschedule::ctx::TuneContext;
use metaschedule::db::{compact_file, CompactionPolicy, Database, JsonFileDb, TuningRecord};
use metaschedule::schedule::Schedule;
use metaschedule::search::mutate;
use metaschedule::sim::{simulate, Target};
use metaschedule::tir::analysis::program_flops;
use metaschedule::tir::structural_hash;
use metaschedule::trace::replay;
use metaschedule::trace::replay::replay_fresh;
use metaschedule::trace::{FactorArg, Inst, Trace};
use metaschedule::util::prop::{check, vec_of, PropConfig};
use metaschedule::util::rng::Rng;
use metaschedule::workloads;

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        ..PropConfig::default()
    }
}

/// Random (m, n, k) with highly-composite extents.
fn rand_shape(rng: &mut Rng) -> (i64, i64, i64) {
    let pick = |rng: &mut Rng| [16, 24, 32, 48, 64, 96, 128][rng.gen_range(7)];
    (pick(rng), pick(rng), pick(rng))
}

#[test]
fn prop_random_transformations_preserve_flops_and_integrity() {
    check(
        cfg(60),
        |rng| {
            let (m, n, k) = rand_shape(rng);
            (m, n, k, rng.next_u64())
        },
        |&(m, n, k, seed)| {
            let prog = workloads::matmul(1, m, n, k);
            let flops = program_flops(&prog);
            let mut s = Schedule::new(prog, seed);
            let mut rng = Rng::seed_from_u64(seed ^ 0x5eed);
            let b = s.get_block("matmul").unwrap();
            // Apply a random sequence of structure-preserving primitives.
            for _ in 0..6 {
                let loops = s.get_loops(b).unwrap();
                if loops.is_empty() {
                    break;
                }
                match rng.gen_range(4) {
                    0 => {
                        let l = loops[rng.gen_range(loops.len())];
                        if let Ok(t) = s.sample_perfect_tile(l, 2, 0) {
                            let _ = s.split(l, &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)]);
                        }
                    }
                    1 => {
                        if loops.len() >= 2 {
                            let i = rng.gen_range(loops.len() - 1);
                            let _ = s.fuse(&loops[i..i + 2]);
                        }
                    }
                    2 => {
                        if loops.len() >= 2 {
                            let mut order = loops.clone();
                            let a = rng.gen_range(order.len());
                            let c = rng.gen_range(order.len());
                            order.swap(a, c);
                            let _ = s.reorder(&order);
                        }
                    }
                    _ => {
                        let l = loops[rng.gen_range(loops.len())];
                        let _ = s.unroll(l);
                    }
                }
            }
            s.prog.check_integrity().is_ok() && (program_flops(&s.prog) - flops).abs() < 1e-6
        },
    );
}

#[test]
fn prop_traces_replay_deterministically() {
    // For any design-space trace: replay(trace) == original program, and
    // two replays agree with each other.
    check(
        cfg(20),
        |rng| rng.next_u64(),
        |&seed| {
            let prog = workloads::fused_dense(64, 128, 64);
            let ctx = TuneContext::generic(Target::cpu_avx512());
            let designs = ctx.generate(&prog, seed);
            designs.iter().all(|d| {
                let a = replay(&d.trace, &prog, 1).unwrap();
                let b = replay(&d.trace, &prog, 2).unwrap();
                structural_hash(&a.prog) == structural_hash(&d.prog)
                    && structural_hash(&a.prog) == structural_hash(&b.prog)
            })
        },
    );
}

#[test]
fn prop_fresh_samples_stay_on_support() {
    // Fork-and-sample either fails validation or yields a program with
    // identical semantics proxies (flops) and valid structure.
    check(
        cfg(30),
        |rng| rng.next_u64(),
        |&seed| {
            let prog = workloads::matmul(1, 64, 64, 64);
            let flops = program_flops(&prog);
            let ctx = TuneContext::generic(Target::cpu_avx512());
            let designs = ctx.generate(&prog, 1);
            designs.iter().all(|d| match replay_fresh(&d.trace, &prog, seed) {
                Ok(s) => {
                    s.prog.check_integrity().is_ok()
                        && (program_flops(&s.prog) - flops).abs() < 1e-6
                }
                Err(_) => true, // off-support draws are legitimately rejected
            })
        },
    );
}

#[test]
fn prop_mutations_preserve_semantics() {
    check(
        cfg(30),
        |rng| rng.next_u64(),
        |&seed| {
            let prog = workloads::fused_dense(64, 128, 64);
            let flops = program_flops(&prog);
            let ctx = TuneContext::generic(Target::cpu_avx512());
            let designs = ctx.generate(&prog, 3);
            let mut rng = Rng::seed_from_u64(seed);
            designs.iter().all(|d| {
                for _ in 0..4 {
                    if let Some(m) = mutate(&d.trace, &prog, &mut rng, seed) {
                        if m.prog.check_integrity().is_err() {
                            return false;
                        }
                        if (program_flops(&m.prog) - flops).abs() > 1e-6 {
                            return false;
                        }
                    }
                }
                true
            })
        },
    );
}

#[test]
fn prop_simulator_deterministic_and_positive() {
    check(
        cfg(40),
        |rng| {
            let (m, n, k) = rand_shape(rng);
            (m, n, k, rng.gen_bool(0.5))
        },
        |&(m, n, k, gpu)| {
            let prog = workloads::matmul(1, m, n, k);
            let target = if gpu { Target::gpu() } else { Target::cpu_avx512() };
            let a = simulate(&prog, &target).unwrap();
            let b = simulate(&prog, &target).unwrap();
            a.total_s == b.total_s && a.total_s > 0.0 && a.flops == 2.0 * (m * n * k) as f64
        },
    );
}

#[test]
fn prop_simulator_monotone_in_problem_size() {
    // Double one dimension of a matmul => latency must not decrease.
    check(
        cfg(30),
        |rng| {
            let (m, n, k) = rand_shape(rng);
            (m, n, k, rng.gen_range(3))
        },
        |&(m, n, k, dim)| {
            let t = Target::cpu_avx512();
            let base = simulate(&workloads::matmul(1, m, n, k), &t).unwrap().total_s;
            let (m2, n2, k2) = match dim {
                0 => (m * 2, n, k),
                1 => (m, n * 2, k),
                _ => (m, n, k * 2),
            };
            let bigger = simulate(&workloads::matmul(1, m2, n2, k2), &t)
                .unwrap()
                .total_s;
            bigger >= base * 0.99
        },
    );
}

#[test]
fn prop_scheduled_programs_compute_identical_values() {
    // The strongest invariant in the suite: every schedule the composed
    // space produces (and every valid mutation of it) must compute the
    // SAME values as e_0 on concrete data — checked with the reference
    // interpreter (tir::interp), not a structural proxy. Small shapes
    // keep interpretation fast; tensorized (opaque) schedules cannot be
    // interpreted and are skipped.
    use metaschedule::tir::interp::{semantic_distance, InterpError};
    check(
        cfg(10),
        |rng| rng.next_u64(),
        |&seed| {
            let prog = workloads::fused_dense(8, 16, 8);
            let ctx = TuneContext::generic(Target::cpu_avx512());
            let designs = ctx.generate(&prog, seed);
            let mut rng = Rng::seed_from_u64(seed ^ 0xabcd);
            for d in &designs {
                match semantic_distance(&prog, &d.prog, seed) {
                    Ok(dist) => {
                        if dist > 1e-4 {
                            return Err(format!("design diverges by {dist}"));
                        }
                    }
                    Err(InterpError::OpaqueBlock(_)) => {}
                    Err(e) => return Err(format!("interp error: {e}")),
                }
                // And one mutation of it.
                if let Some(m) = mutate(&d.trace, &prog, &mut rng, seed) {
                    match semantic_distance(&prog, &m.prog, seed) {
                        Ok(dist) => {
                            if dist > 1e-4 {
                                return Err(format!("mutation diverges by {dist}"));
                            }
                        }
                        Err(InterpError::OpaqueBlock(_)) => {}
                        Err(e) => return Err(format!("interp error: {e}")),
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_random_primitive_sequences_compute_identical_values() {
    // Same invariant over raw primitive sequences (not just module-made
    // schedules): random split/fuse/reorder/parallel/vectorize/unroll
    // chains on a small matmul leave the interpreted output unchanged.
    use metaschedule::tir::interp::semantic_distance;
    check(
        cfg(20),
        |rng| rng.next_u64(),
        |&seed| {
            let prog = workloads::matmul(1, 8, 12, 16);
            let mut s = Schedule::new(prog.clone(), seed);
            let mut rng = Rng::seed_from_u64(seed ^ 0xfeed);
            let b = s.get_block("matmul").unwrap();
            for _ in 0..5 {
                let loops = s.get_loops(b).unwrap();
                if loops.is_empty() {
                    break;
                }
                match rng.gen_range(5) {
                    0 => {
                        let l = loops[rng.gen_range(loops.len())];
                        if let Ok(t) = s.sample_perfect_tile(l, 2, 0) {
                            let _ = s.split(l, &[FactorArg::Rv(t[0].0), FactorArg::Rv(t[1].0)]);
                        }
                    }
                    1 => {
                        if loops.len() >= 2 {
                            let i = rng.gen_range(loops.len() - 1);
                            let _ = s.fuse(&loops[i..i + 2]);
                        }
                    }
                    2 => {
                        let mut order = loops.clone();
                        let a = rng.gen_range(order.len());
                        let c = rng.gen_range(order.len());
                        order.swap(a, c);
                        let _ = s.reorder(&order);
                    }
                    3 => {
                        let l = loops[rng.gen_range(loops.len())];
                        let _ = s.parallel(l);
                    }
                    _ => {
                        let l = loops[rng.gen_range(loops.len())];
                        let _ = s.unroll(l);
                    }
                }
            }
            let d = semantic_distance(&prog, &s.prog, seed).map_err(|e| e.to_string())?;
            if d > 1e-5 {
                return Err(format!("primitive chain diverges by {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_perfect_tile_enumeration_sound() {
    use metaschedule::schedule::sampling::enumerate_perfect_tiles;
    check(
        cfg(50),
        |rng| {
            let extent = [4, 6, 12, 24, 36, 60, 96, 120][rng.gen_range(8)];
            let n = 2 + rng.gen_range(3);
            let max_inner = [0, 4, 16][rng.gen_range(3)];
            (extent, n, max_inner)
        },
        |&(extent, n, max_inner)| {
            let tiles = enumerate_perfect_tiles(extent, n, max_inner);
            !tiles.is_empty()
                && tiles.iter().all(|t| {
                    t.len() == n
                        && t.iter().product::<i64>() == extent
                        && t.iter().all(|&f| f >= 1)
                        && (max_inner == 0 || *t.last().unwrap() <= max_inner)
                })
                && {
                    // No duplicates.
                    let mut s: Vec<Vec<i64>> = tiles.as_ref().clone();
                    s.sort();
                    s.dedup();
                    s.len() == tiles.len()
                }
        },
    );
}

#[test]
fn prop_chain_split_rngs_never_collide() {
    // The parallel search derives one RNG stream per (round, chain, kind)
    // from the root seed. For any seed, sibling streams must not collide:
    // no two of the first chains' generators may share any prefix of
    // their first 1k draws (a collision would mean two chains exploring
    // identical candidates — silent loss of population diversity).
    check(
        cfg(10),
        |rng| rng.next_u64(),
        |&seed| {
            const STREAMS: u64 = 8;
            const DRAWS: usize = 1000;
            let mut streams: Vec<Vec<u64>> = Vec::new();
            for c in 0..STREAMS {
                let mut r = Rng::for_stream(seed, c);
                streams.push((0..DRAWS).map(|_| r.next_u64()).collect());
            }
            for a in 0..streams.len() {
                for b in (a + 1)..streams.len() {
                    // Identical draw at the same position = the streams
                    // entered lockstep; forbid any overlap at all beyond
                    // chance (u64 draws colliding by chance is ~0).
                    let collisions = streams[a]
                        .iter()
                        .zip(&streams[b])
                        .filter(|(x, y)| x == y)
                        .count();
                    if collisions != 0 {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// One random record: (workload, latencies — empty = failure, cand hash).
type RandRecord = (usize, Vec<f64>, u64);

/// Build a JSONL db from the case, compact it, and check the compaction
/// contract: `best_latency` and `query_top_k(j)` (j <= top_k) answer
/// identically, no failure hash leaves the dedup set, and a second
/// compaction is a byte-for-byte no-op.
fn check_compaction_case(n_workloads: usize, recs: &[RandRecord], top_k: usize) -> Result<(), String> {
    let path = std::env::temp_dir().join(format!("ms-prop-compact-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let mut db = JsonFileDb::open(&path)?;
    for w in 0..n_workloads {
        db.register_workload(&format!("w{w}"), w as u64 + 1, "cpu");
    }
    for (i, (w, lats, cand)) in recs.iter().enumerate() {
        db.commit_record(TuningRecord {
            workload: *w,
            trace: Trace {
                insts: vec![Inst::GetBlock { name: format!("b{i}"), out: 0 }],
            },
            latencies: lats.clone(),
            target: "cpu".into(),
            seed: 1,
            round: i as u64,
            cand_hash: *cand,
            sim_version: "simtest".into(),
            rule_set: String::new(),
            objective: String::new(),
        });
    }
    // Reference answers from the uncompacted database.
    let ref_best: Vec<Option<f64>> = (0..n_workloads).map(|w| db.best_latency(w)).collect();
    let ref_top: Vec<Vec<Vec<TuningRecord>>> = (0..n_workloads)
        .map(|w| (1..=top_k).map(|j| db.query_top_k(w, j)).collect())
        .collect();
    drop(db);

    let policy = CompactionPolicy::keep_top(top_k);
    compact_file(&path, &policy, false)?;
    let bytes_once = std::fs::read(&path).map_err(|e| e.to_string())?;
    let db = JsonFileDb::open(&path)?;
    if db.skipped_lines() != 0 {
        return Err("compacted file has unparseable lines".into());
    }
    for w in 0..n_workloads {
        if db.best_latency(w) != ref_best[w] {
            return Err(format!(
                "workload {w}: best_latency {:?} != {:?} after compaction",
                db.best_latency(w),
                ref_best[w]
            ));
        }
        for j in 1..=top_k {
            if db.query_top_k(w, j) != ref_top[w][j - 1] {
                return Err(format!("workload {w}: query_top_k({j}) changed after compaction"));
            }
        }
    }
    // Every failure hash must still answer has_candidate (dedup safety).
    for (w, lats, cand) in recs {
        if lats.is_empty() && !db.has_candidate(*w, *cand) {
            return Err(format!("workload {w}: failure hash {cand:016x} dropped by compaction"));
        }
    }
    drop(db);
    // Idempotence: compact(compact(f)) == compact(f), byte for byte.
    compact_file(&path, &policy, false)?;
    let bytes_twice = std::fs::read(&path).map_err(|e| e.to_string())?;
    if bytes_once != bytes_twice {
        return Err("second compaction changed the file".into());
    }
    let _ = std::fs::remove_file(&path);
    Ok(())
}

#[test]
fn prop_compaction_preserves_queries_dedup_and_is_idempotent() {
    const TOP_K: usize = 3;
    check(
        cfg(30),
        |rng| {
            let n_workloads = 1 + rng.gen_range(3);
            let recs: Vec<RandRecord> = vec_of(rng, 0, 24, |rng| {
                let w = rng.gen_range(n_workloads);
                let n_lat = rng.gen_range(3); // 0 latencies = failed candidate
                // Latencies drawn from a small grid so exact ties are
                // common — the tie-break (commit order) is part of the
                // contract under test.
                let lats: Vec<f64> = (0..n_lat).map(|_| (1 + rng.gen_range(8)) as f64 * 0.5e-6).collect();
                (w, lats, rng.next_u64())
            });
            (n_workloads, recs)
        },
        |(n_workloads, recs)| check_compaction_case(*n_workloads, recs, TOP_K),
    );
}

#[test]
fn prop_stale_rules_compaction_partitions_exactly() {
    // Random records over a handful of rule-set labels: compacting with
    // one label marked stale must (a) drop every record with that label,
    // failures included, (b) answer the per-workload queries identically
    // to first deleting those records and then compacting without the
    // stale set (drop-then-gc == gc-with-stale-set), and (c) stay
    // idempotent.
    use metaschedule::db::keep_mask;
    const LABELS: [&str; 3] =
        ["live-a #aaaaaaaa", "live-b #bbbbbbbb", "ghost #cccccccc"];
    check(
        cfg(40),
        |rng| {
            let recs: Vec<(usize, Vec<f64>, u64, usize)> = vec_of(rng, 1, 24, |rng| {
                let w = rng.gen_range(2);
                let n_lat = rng.gen_range(3);
                let lats: Vec<f64> =
                    (0..n_lat).map(|_| (1 + rng.gen_range(8)) as f64 * 0.5e-6).collect();
                (w, lats, rng.next_u64(), rng.gen_range(LABELS.len()))
            });
            recs
        },
        |recs| {
            let mk = |(w, lats, cand, label): &(usize, Vec<f64>, u64, usize)| TuningRecord {
                workload: *w,
                trace: Trace { insts: vec![] },
                latencies: lats.clone(),
                target: "cpu".into(),
                seed: 1,
                round: *cand,
                cand_hash: *cand,
                sim_version: "simtest".into(),
                rule_set: LABELS[*label].to_string(),
                objective: String::new(),
            };
            let records: Vec<TuningRecord> = recs.iter().map(mk).collect();
            let stale_policy = CompactionPolicy {
                top_k: 2,
                stale_rule_sets: vec!["ghost #cccccccc".to_string()],
            };
            let mask = keep_mask(&records, &stale_policy);
            // (a) no ghost survives; all live failures survive.
            for (r, &keep) in records.iter().zip(&mask) {
                if r.rule_set.starts_with("ghost") && keep {
                    return Err("stale record survived".to_string());
                }
                if !r.rule_set.starts_with("ghost") && r.is_failed() && !keep {
                    return Err("live failure dropped".to_string());
                }
            }
            // (b) equivalence with delete-then-plain-gc.
            let pre_deleted: Vec<TuningRecord> = records
                .iter()
                .filter(|r| !r.rule_set.starts_with("ghost"))
                .cloned()
                .collect();
            let plain = CompactionPolicy::keep_top(2);
            let expected: Vec<&TuningRecord> = pre_deleted
                .iter()
                .zip(keep_mask(&pre_deleted, &plain))
                .filter(|(_, k)| *k)
                .map(|(r, _)| r)
                .collect();
            let got: Vec<&TuningRecord> = records
                .iter()
                .zip(&mask)
                .filter(|(_, k)| **k)
                .map(|(r, _)| r)
                .collect();
            if got.len() != expected.len() || got.iter().zip(&expected).any(|(a, b)| **a != **b) {
                return Err("stale-set gc differs from delete-then-gc".to_string());
            }
            // (c) idempotence.
            let survivors: Vec<TuningRecord> = got.into_iter().cloned().collect();
            if !keep_mask(&survivors, &stale_policy).iter().all(|&k| k) {
                return Err("stale-rules compaction not idempotent".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_probe_fingerprint_is_content_function() {
    // The watcher signature must change whenever bytes change (head,
    // middle, or tail of the file) and must be a pure function of the
    // content — same bytes, same fingerprint — regardless of length.
    use metaschedule::db::probe;
    let path = std::env::temp_dir().join(format!("ms-prop-probe-{}.jsonl", std::process::id()));
    check(
        cfg(30),
        |rng| {
            // Up to 3 probe windows' worth of bytes: the range over which
            // the fingerprint guarantees full coverage (beyond that it
            // samples).
            let len = 1 + rng.gen_range(3000);
            let flip = rng.gen_range(len);
            (len, flip, rng.next_u64())
        },
        |&(len, flip, seed)| {
            let mut bytes: Vec<u8> =
                (0..len).map(|i| b'a' + ((i as u64 ^ seed) % 23) as u8).collect();
            std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
            let s1 = probe(&path).ok_or("probe failed")?;
            let s1b = probe(&path).ok_or("probe failed")?;
            if s1.content_fp != s1b.content_fp {
                return Err("fingerprint not deterministic".into());
            }
            bytes[flip] = if bytes[flip] == b'z' { b'y' } else { b'z' };
            std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
            let s2 = probe(&path).ok_or("probe failed")?;
            if s1.content_fp == s2.content_fp {
                return Err(format!(
                    "same-length rewrite at byte {flip}/{len} not detected"
                ));
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_file(&path);
}

/// A tuning record for the sharding properties below: real trace, one
/// latency, hash-distinct candidates.
fn shard_rec(workload: usize, i: usize, lat: Option<f64>) -> TuningRecord {
    TuningRecord {
        workload,
        trace: Trace { insts: vec![Inst::GetBlock { name: format!("b{i}"), out: 0 }] },
        latencies: lat.into_iter().collect(),
        target: "cpu".into(),
        seed: 1,
        round: i as u64,
        cand_hash: ((workload as u64) << 32) | i as u64,
        sim_version: "simtest".into(),
        rule_set: String::new(),
        objective: String::new(),
    }
}

/// Scratch dir helper for the sharding properties: one dir per test,
/// wiped between cases.
fn fresh_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ms-prop-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn prop_shard_routing_is_stable_across_sessions() {
    // For any shard count and any workload hash: the workload's records
    // land in exactly the `shard_of` file, stay there across a close +
    // reopen, and later commits through the reopened handle route to the
    // same shard — a record never migrates between shards behind the
    // operator's back.
    use metaschedule::db::{shard_of, ShardedDb};
    check(
        cfg(15),
        |rng| {
            let shards = 1 + rng.gen_range(8);
            let mut hashes: Vec<u64> = (0..1 + rng.gen_range(5)).map(|_| rng.next_u64()).collect();
            hashes.sort_unstable();
            hashes.dedup();
            (shards, hashes)
        },
        |(shards, hashes)| {
            let dir = fresh_dir("route");
            let mut db = ShardedDb::create(&dir, *shards).map_err(|e| e.to_string())?;
            for (w, &h) in hashes.iter().enumerate() {
                let wid = db.register_workload(&format!("w{w}"), h, "cpu");
                db.commit_record(shard_rec(wid, w, Some(1e-5)));
            }
            drop(db);
            let mut db = ShardedDb::open(&dir).map_err(|e| e.to_string())?;
            for (w, &h) in hashes.iter().enumerate() {
                let home = shard_of(h, *shards);
                for s in 0..*shards {
                    let found = db.shard(s).find_workload(h, "cpu").is_some();
                    if found != (s == home) {
                        return Err(format!(
                            "hash {h:016x} with {shards} shard(s): found={found} in shard {s}, home {home}"
                        ));
                    }
                }
                // A post-reopen commit must grow the home shard only.
                let before: Vec<usize> = (0..*shards).map(|s| db.shard(s).num_records()).collect();
                let wid = db.find_workload(h, "cpu").ok_or("workload lost on reopen")?;
                db.commit_record(shard_rec(wid, 1000 + w, Some(2e-5)));
                for s in 0..*shards {
                    let grew = db.shard(s).num_records() - before[s];
                    if grew != usize::from(s == home) {
                        return Err(format!("commit for {h:016x} changed shard {s} (home {home})"));
                    }
                }
            }
            let _ = std::fs::remove_dir_all(&dir);
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_compaction_idempotent_per_shard_byte_for_byte() {
    // Parallel per-shard compaction must be idempotent exactly like the
    // single-file compactor: a second pass changes no shard file by even
    // one byte, and best-latency answers survive the first pass.
    use metaschedule::db::{shard_file_name, ShardedDb};
    check(
        cfg(12),
        |rng| {
            let shards = 1 + rng.gen_range(6);
            let n_workloads = 1 + rng.gen_range(4);
            let recs: Vec<RandRecord> = vec_of(rng, 0, 24, |rng| {
                let w = rng.gen_range(n_workloads);
                let n_lat = rng.gen_range(3);
                let lats: Vec<f64> =
                    (0..n_lat).map(|_| (1 + rng.gen_range(8)) as f64 * 0.5e-6).collect();
                (w, lats, rng.next_u64())
            });
            (shards, n_workloads, recs)
        },
        |(shards, n_workloads, recs)| {
            let dir = fresh_dir("shard-compact");
            let mut db = ShardedDb::create(&dir, *shards).map_err(|e| e.to_string())?;
            for w in 0..*n_workloads {
                db.register_workload(&format!("w{w}"), w as u64 + 1, "cpu");
            }
            for (i, (w, lats, cand)) in recs.iter().enumerate() {
                let mut r = shard_rec(*w, i, None);
                r.latencies = lats.clone();
                r.cand_hash = *cand;
                db.commit_record(r);
            }
            let ref_best: Vec<Option<f64>> =
                (0..*n_workloads).map(|w| db.best_latency(w)).collect();
            let policy = CompactionPolicy::keep_top(3);
            db.compact_parallel(&policy, 2).map_err(|e| e.to_string())?;
            let bytes_once: Vec<Vec<u8>> = (0..*shards)
                .map(|s| std::fs::read(dir.join(shard_file_name(s))).unwrap_or_default())
                .collect();
            for w in 0..*n_workloads {
                if db.best_latency(w) != ref_best[w] {
                    return Err(format!("workload {w}: best latency changed by compaction"));
                }
            }
            let _ = db.compact_parallel(&policy, 2).map_err(|e| e.to_string())?;
            for s in 0..*shards {
                let again = std::fs::read(dir.join(shard_file_name(s))).unwrap_or_default();
                if again != bytes_once[s] {
                    return Err(format!("second compaction changed shard {s}"));
                }
            }
            let _ = std::fs::remove_dir_all(&dir);
            Ok(())
        },
    );
}

#[test]
fn prop_migration_preserves_every_answer_byte_for_byte() {
    // Migrating a single-file db to any shard count is invisible to
    // readers: workload ids survive, and `query_top_k` returns records
    // whose JSON serialization is byte-identical to the source's.
    use metaschedule::db::migrate_from_file;
    check(
        cfg(12),
        |rng| {
            let shards = 1 + rng.gen_range(8);
            let n_workloads = 1 + rng.gen_range(4);
            let recs: Vec<RandRecord> = vec_of(rng, 0, 20, |rng| {
                let w = rng.gen_range(n_workloads);
                let n_lat = rng.gen_range(3);
                let lats: Vec<f64> =
                    (0..n_lat).map(|_| (1 + rng.gen_range(8)) as f64 * 0.5e-6).collect();
                (w, lats, rng.next_u64())
            });
            (shards, n_workloads, recs)
        },
        |(shards, n_workloads, recs)| {
            let src = std::env::temp_dir()
                .join(format!("ms-prop-migrate-src-{}.jsonl", std::process::id()));
            let dest = fresh_dir("migrate-dest");
            let _ = std::fs::remove_file(&src);
            let mut db = JsonFileDb::open(&src).map_err(|e| e.to_string())?;
            for w in 0..*n_workloads {
                db.register_workload(&format!("w{w}"), (w as u64 + 1) * 7, "cpu");
            }
            for (i, (w, lats, cand)) in recs.iter().enumerate() {
                let mut r = shard_rec(*w, i, None);
                r.latencies = lats.clone();
                r.cand_hash = *cand;
                db.commit_record(r);
            }
            drop(db);
            let (sharded, skipped) =
                migrate_from_file(&src, &dest, *shards).map_err(|e| e.to_string())?;
            if skipped != 0 {
                return Err("clean source reported skipped lines".into());
            }
            let source = JsonFileDb::open(&src).map_err(|e| e.to_string())?;
            for w in 0..*n_workloads {
                let a = source.query_top_k(w, 8);
                let b = sharded.query_top_k(w, 8);
                let aj: Vec<String> = a.iter().map(|r| r.to_json().to_string()).collect();
                let bj: Vec<String> = b.iter().map(|r| r.to_json().to_string()).collect();
                if aj != bj {
                    return Err(format!(
                        "workload {w}: top-k diverged after migration to {shards} shard(s)"
                    ));
                }
                if source.has_candidate(w, 12345) != sharded.has_candidate(w, 12345) {
                    return Err(format!("workload {w}: has_candidate diverged"));
                }
            }
            let _ = std::fs::remove_file(&src);
            let _ = std::fs::remove_dir_all(&dest);
            Ok(())
        },
    );
}

#[test]
fn prop_vendor_latency_scale_invariance() {
    // Vendor model: scaling a GEMM's flops scales its compute-bound
    // latency roughly linearly (sanity of the roofline form).
    check(
        cfg(20),
        |rng| [256, 384, 512][rng.gen_range(3)],
        |&n| {
            let t = Target::cpu_avx512();
            let small = metaschedule::baselines::vendor_latency(
                &workloads::matmul(1, n, n, n),
                &t,
            );
            let big = metaschedule::baselines::vendor_latency(
                &workloads::matmul(1, 2 * n, 2 * n, 2 * n),
                &t,
            );
            big > small * 4.0 && big < small * 16.0
        },
    );
}

#[test]
fn prop_histogram_bucket_index_brackets_every_value() {
    // Each value lands in the smallest bucket whose upper bound covers
    // it: v <= bound(i), and the previous bucket would have been too
    // small. Monotone by construction, so bucket-sorted order agrees
    // with value-sorted order (what `quantile` relies on).
    use metaschedule::telemetry::Histogram;
    check(
        cfg(200),
        |rng| rng.next_u64() >> (rng.gen_range(64) as u32),
        |&v| {
            let i = Histogram::bucket_index(v);
            if let Some(b) = Histogram::bound(i) {
                if v > b {
                    return false;
                }
            }
            if i > 0 {
                if let Some(prev) = Histogram::bound(i - 1) {
                    if v <= prev {
                        return false;
                    }
                }
            }
            Histogram::bucket_index(v.saturating_add(1)) >= i
        },
    );
}

#[test]
fn prop_histogram_conserves_counts_and_bounds_quantiles() {
    // Recording any sample set: count/sum are conserved exactly, and
    // every quantile is an upper bound within 2x of the true
    // nearest-rank quantile (the log-scale bucket resolution).
    use metaschedule::telemetry::Histogram;
    check(
        cfg(60),
        |rng| {
            let n = 1 + rng.gen_range(64);
            (0..n).map(|_| 1 + (rng.next_u64() % (1 << 20))).collect::<Vec<u64>>()
        },
        |samples| {
            let h = Histogram::new();
            for &s in samples {
                h.observe(s);
            }
            if h.count() != samples.len() as u64 {
                return false;
            }
            if h.sum() != samples.iter().sum::<u64>() {
                return false;
            }
            if h.bucket_counts().iter().sum::<u64>() != samples.len() as u64 {
                return false;
            }
            let mut sorted = samples.clone();
            sorted.sort_unstable();
            for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
                let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
                let truth = sorted[rank - 1];
                let est = h.quantile(q);
                if est < truth || est >= 2 * truth.max(1) {
                    return false;
                }
            }
            true
        },
    );
}

/// Structured synthetic ranking data: the score is a smooth function of
/// two features (so the order is learnable), drawn on a grid (so exact
/// label ties occur and exercise the pair filter).
fn rank_case(seed: u64, n: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let a = rng.gen_range(100) as f64 / 10.0;
        let b = rng.gen_range(100) as f64 / 10.0;
        xs.push(vec![a, b]);
        ys.push(3.0 * a - b + 0.05 * a * b);
    }
    (xs, ys)
}

#[test]
fn prop_rank_objective_is_order_consistent_with_labels() {
    // The PairwiseRank objective's whole contract: for any seed, the
    // fitted model's predictions agree with the label order on a clear
    // majority of untied training pairs (the cost model only ever ranks
    // candidates — calibrated magnitudes are Regression's job).
    use metaschedule::cost_model::Gbt;
    check(
        cfg(12),
        |rng| rng.next_u64(),
        |&seed| {
            let (xs, ys) = rank_case(seed, 60);
            let ws = vec![1.0; xs.len()];
            let mut model = Gbt::new(40, 3, 0.1);
            model.fit_ranked(&xs, &ys, &ws, seed);
            let preds = model.predict(&xs);
            let mut agree = 0usize;
            let mut total = 0usize;
            for i in 0..xs.len() {
                for j in (i + 1)..xs.len() {
                    if ys[i] == ys[j] {
                        continue;
                    }
                    total += 1;
                    if (preds[i] - preds[j]) * (ys[i] - ys[j]) > 0.0 {
                        agree += 1;
                    }
                }
            }
            if total == 0 {
                return Err("degenerate case: every label tied".to_string());
            }
            let c = agree as f64 / total as f64;
            if c < 0.75 {
                return Err(format!("training concordance {c:.3} below 0.75"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rank_fit_invariant_under_monotone_relabeling_where_regression_is_not() {
    // Scaling labels by 2^k is a bit-exact strictly monotone bijection
    // on finite doubles (pure exponent shift: no rounding, no new ties),
    // so the label ORDER — the only thing the rank objective may consume
    // — is unchanged, and the ranked fit must be bit-identical. The
    // regression objective tracks label MAGNITUDE and must differ.
    use metaschedule::cost_model::Gbt;
    check(
        cfg(10),
        |rng| (rng.next_u64(), rng.gen_range(9)),
        |&(seed, kidx)| {
            let scale = (2.0f64).powi(kidx as i32 - 3); // 2^-3 .. 2^5
            let (xs, ys) = rank_case(seed, 48);
            let ys_scaled: Vec<f64> = ys.iter().map(|y| y * scale).collect();
            let ws = vec![1.0; xs.len()];
            let ranked = |labels: &[f64]| {
                let mut m = Gbt::new(30, 3, 0.1);
                m.fit_ranked(&xs, labels, &ws, seed);
                m
            };
            let a = ranked(&ys);
            let b = ranked(&ys_scaled);
            for x in &xs {
                let (pa, pb) = (a.predict_one(x), b.predict_one(x));
                if pa != pb {
                    return Err(format!(
                        "ranked fit not invariant under x{scale} relabeling: {pa} != {pb}"
                    ));
                }
            }
            if scale != 1.0 {
                let regression = |labels: &[f64]| {
                    let mut m = Gbt::new(30, 3, 0.1);
                    m.fit(&xs, labels);
                    m
                };
                let ra = regression(&ys);
                let rb = regression(&ys_scaled);
                if xs.iter().all(|x| ra.predict_one(x) == rb.predict_one(x)) {
                    return Err(format!(
                        "regression fit unexpectedly invariant under x{scale} relabeling"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_metrics_render_always_parses_as_exposition() {
    // Whatever mix of instruments and values a registry holds, its
    // `/metrics` rendering must satisfy our own exposition parser (the
    // same check CI runs against the live server).
    use metaschedule::telemetry::{parse_exposition, Metrics};
    check(
        cfg(40),
        |rng| (0..8).map(|_| (rng.gen_range(3), rng.next_u64() % 1000)).collect::<Vec<(usize, u64)>>(),
        |plan| {
            let m = Metrics::new();
            for (i, &(kind, v)) in plan.iter().enumerate() {
                match kind {
                    0 => m.counter(&format!("c{i}_total"), "a counter").add(v),
                    1 => m.gauge(&format!("g{i}"), "a gauge").set(v as i64 - 500),
                    _ => m.histogram(&format!("h{i}_micros"), "a histogram").observe(v),
                }
            }
            let parsed = match parse_exposition(&m.render()) {
                Ok(p) => p,
                Err(_) => return false,
            };
            // Every plain counter value must round-trip exactly.
            plan.iter().enumerate().all(|(i, &(kind, v))| {
                kind != 0 || parsed.get(&format!("c{i}_total")).copied() == Some(v as f64)
            })
        },
    );
}
