//! Graph-fusion integration tests: the fusion pass and its emitted fused
//! programs, exercised over the whole model zoo.
//!
//! Invariants pinned here:
//! - fusing never creates or destroys work (FLOPs and op-weight conserve)
//! - every fused program is a valid schedulable program the simulator
//!   accepts (over every model in the zoo)
//! - the pass is deterministic and idempotent
//! - opaque ops are hard fusion boundaries
//! - fused extraction yields strictly fewer tasks than per-op extraction
//!   on the models the paper evaluates end-to-end

use metaschedule::graph::{
    self, extract_fused_tasks, extract_tasks, fuse, fuse_group_program, FusionKind, OpGraph,
};
use metaschedule::sim::{simulate, Target};
use metaschedule::tir::analysis::program_flops;
use metaschedule::tir::BlockBody;
use metaschedule::workloads;

#[test]
fn fused_programs_conserve_flops() {
    for name in graph::MODEL_NAMES {
        let g = graph::graph_by_name(name).unwrap();
        for group in fuse(&g) {
            let fused = fuse_group_program(&g, &group);
            fused.check_integrity().unwrap();
            let member_flops: f64 = group
                .members
                .iter()
                .map(|&i| program_flops(&g.node(i).prog))
                .sum();
            let fused_flops = program_flops(&fused);
            assert!(
                (fused_flops - member_flops).abs() <= member_flops * 1e-9,
                "{name}: fused {fused_flops} vs members {member_flops}"
            );
        }
    }
}

#[test]
fn simulator_accepts_every_fused_task_in_the_zoo() {
    let cpu = Target::cpu_avx512();
    for name in graph::MODEL_NAMES {
        let g = graph::graph_by_name(name).unwrap();
        for task in extract_fused_tasks(&g) {
            let r = simulate(&task.prog, &cpu)
                .unwrap_or_else(|e| panic!("{name}/{}: {e:?}", task.name));
            assert!(r.total_s > 0.0, "{name}/{}: zero latency", task.name);
        }
    }
}

#[test]
fn fusion_is_deterministic_and_idempotent_across_the_zoo() {
    for name in graph::MODEL_NAMES {
        let g = graph::graph_by_name(name).unwrap();
        let a = fuse(&g);
        let b = fuse(&g);
        assert_eq!(a.len(), b.len(), "{name}");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.members, y.members, "{name}");
            assert_eq!(x.count, y.count, "{name}");
            assert_eq!(x.kind, y.kind, "{name}");
        }
        // Idempotence: re-fusing the already-fused programs (as an
        // edge-free graph — fusion consumed the dataflow) only yields
        // singletons, so the task set is a fixed point.
        let tasks = extract_fused_tasks(&g);
        let refused: Vec<(metaschedule::tir::Program, usize)> =
            tasks.iter().map(|t| (t.prog.clone(), t.weight)).collect();
        let g2 = OpGraph::from_ops(&refused);
        let again = fuse(&g2);
        assert!(again.iter().all(|gr| gr.members.len() == 1), "{name}");
        assert_eq!(extract_fused_tasks(&g2).len(), tasks.len(), "{name}");
    }
}

#[test]
fn fused_extraction_is_smaller_and_conserves_weight() {
    for name in ["resnet50", "bert-base"] {
        let g = graph::graph_by_name(name).unwrap();
        let per_op = extract_tasks(&g.ops());
        let fused = extract_fused_tasks(&g);
        assert!(
            fused.len() < per_op.len(),
            "{name}: {} fused vs {} per-op",
            fused.len(),
            per_op.len()
        );
        let groups = fuse(&g);
        let op_weight: usize = g.nodes().iter().map(|n| n.count).sum();
        let group_weight: usize = groups.iter().map(|gr| gr.op_weight()).sum();
        assert_eq!(op_weight, group_weight, "{name}");
        let task_weight: usize = fused.iter().map(|t| t.weight).sum();
        let group_count: usize = groups.iter().map(|gr| gr.count).sum();
        assert_eq!(task_weight, group_count, "{name}");
    }
}

#[test]
fn opaque_ops_are_never_fused_across() {
    // dense -> add2d fuses when dense is transparent; an opaque dense is
    // a hard boundary even with the same dataflow edge.
    let mut g = OpGraph::new();
    let mut dense = workloads::dense(16, 16, 16);
    let add = workloads::add2d(16, 16);
    let d = g.add(dense.clone(), 1);
    let a = g.add(add.clone(), 1);
    g.connect(d, a);
    assert!(fuse(&g).iter().any(|gr| gr.members.len() == 2));

    let blocks = dense.blocks();
    let b = *blocks.last().unwrap();
    dense.block_data_mut(b).body = BlockBody::Opaque { flops_per_instance: 1.0 };
    let mut g = OpGraph::new();
    let d = g.add(dense, 1);
    let a = g.add(add, 1);
    g.connect(d, a);
    let groups = fuse(&g);
    assert!(groups.iter().all(|gr| gr.members.len() == 1));
    assert_eq!(groups[0].kind, FusionKind::Opaque);
}
