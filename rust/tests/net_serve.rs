//! Integration tests for the zero-dep HTTP serving front
//! (`serve --listen`): a real `HttpServer` bound to an ephemeral port on
//! a sharded database, exercised by real `TcpStream` clients —
//! concurrent hits, misses, malformed requests, admission control, and
//! graceful shutdown with an accurate traffic report.

use std::path::PathBuf;

use metaschedule::db::{AnyDb, Database, ShardedDb, TuningRecord};
use metaschedule::serve::net::{get_request, http_roundtrip, split_response};
use metaschedule::serve::{HttpConfig, HttpReport, HttpServer, ServeConfig};
use metaschedule::sim::Target;
use metaschedule::tir::structural_hash;
use metaschedule::trace::{Inst, Trace};
use metaschedule::util::json::Json;
use metaschedule::workloads;

/// Scratch directory removed on drop, panic included.
struct DirGuard(PathBuf);
impl Drop for DirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn tmp_dir(name: &str) -> (PathBuf, DirGuard) {
    let dir = std::env::temp_dir().join(format!("ms-net-serve-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    (dir.clone(), DirGuard(dir))
}

/// A sharded db holding one committed record for the real `GMM` workload
/// (its true structural hash, so `/lookup?workload=GMM` hits).
fn db_with_gmm(dir: &PathBuf) -> AnyDb {
    let w = workloads::by_name("GMM").expect("GMM is a built-in workload");
    let shash = structural_hash(&(w.build)());
    let mut db = ShardedDb::create(dir, 4).unwrap();
    let wid = db.register_workload("GMM", shash, "cpu");
    db.commit_record(TuningRecord {
        workload: wid,
        trace: Trace { insts: vec![Inst::GetBlock { name: "root".into(), out: 0 }] },
        latencies: vec![1.25e-5],
        target: "cpu".into(),
        seed: 1,
        round: 0,
        cand_hash: 7,
        sim_version: "simtest".into(),
        rule_set: String::new(),
        objective: String::new(),
    });
    drop(db);
    AnyDb::open(dir).unwrap()
}

/// Bind on an ephemeral port and run the server on its own thread;
/// returns the resolved address and the join handle yielding the report.
fn start_server(cfg: HttpConfig, db: AnyDb) -> (String, std::thread::JoinHandle<HttpReport>) {
    let server = HttpServer::bind(cfg, Target::by_name("cpu").unwrap()).expect("bind :0");
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run(db));
    (addr, handle)
}

fn read_only_cfg() -> HttpConfig {
    HttpConfig {
        addr: "127.0.0.1:0".into(),
        workers: 3,
        max_pending: 16,
        max_inflight_tunes: 1,
        serve: ServeConfig { miss_trials: 0, ..ServeConfig::default() },
        access_log: None,
    }
}

#[test]
fn concurrent_clients_get_correct_answers_and_malformed_requests_do_not_kill_the_server() {
    let (dir, _guard) = tmp_dir("concurrent");
    let (addr, handle) = start_server(read_only_cfg(), db_with_gmm(&dir));

    // A malformed request first: it must cost its connection a 400 line
    // and nothing else.
    let raw = http_roundtrip(&addr, b"BOGUS\r\n\r\n").unwrap();
    let (status, body) = split_response(&raw).unwrap();
    assert_eq!(status, 400);
    assert!(Json::parse(body.trim()).unwrap().get("error").is_some(), "400 carries an error line");

    // Concurrent clients after the malformed one: hits, a read-only
    // miss, and an unknown workload, all answered correctly.
    let hits = 6;
    let results: Vec<(u16, String)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..hits {
            let addr = addr.clone();
            handles.push(s.spawn(move || {
                let raw = http_roundtrip(&addr, &get_request("/lookup?workload=GMM")).unwrap();
                let (status, body) = split_response(&raw).unwrap();
                (status, body.to_string())
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (status, body) in &results {
        assert_eq!(*status, 200);
        let j = Json::parse(body.trim()).unwrap();
        assert_eq!(j.get("hit").and_then(Json::as_bool), Some(true));
        let lat = j.get("latency_s").and_then(Json::as_f64).unwrap();
        assert!((lat - 1.25e-5).abs() < 1e-12, "served the committed best latency");
    }

    // Read-only miss: SFM is a real workload with no records.
    let raw = http_roundtrip(&addr, &get_request("/lookup?workload=SFM")).unwrap();
    let (status, body) = split_response(&raw).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(body.trim()).unwrap();
    assert_eq!(j.get("hit").and_then(Json::as_bool), Some(false));
    assert_eq!(j.get("tune").and_then(Json::as_str), Some("disabled"));

    // Unknown workload: 404 error line.
    let raw = http_roundtrip(&addr, &get_request("/lookup?workload=NOPE")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 404);

    // Batched report-only lookups: one NDJSON line per name.
    let body = "GMM\nSFM\nNOPE\n";
    let req = format!(
        "POST /batch HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let raw = http_roundtrip(&addr, req.as_bytes()).unwrap();
    let (status, ndjson) = split_response(&raw).unwrap();
    assert_eq!(status, 200);
    let lines: Vec<Json> = ndjson.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 3);
    assert_eq!(lines[0].get("hit").and_then(Json::as_bool), Some(true));
    assert_eq!(lines[1].get("hit").and_then(Json::as_bool), Some(false));
    assert!(lines[2].get("error").is_some());

    // Liveness + stats still answer after all of the above.
    let raw = http_roundtrip(&addr, &get_request("/healthz")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 200);
    let raw = http_roundtrip(&addr, &get_request("/stats")).unwrap();
    let (status, body) = split_response(&raw).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(body.trim()).unwrap();
    assert_eq!(j.get("shards").and_then(Json::as_f64), Some(4.0));

    // Graceful shutdown: answered, then `run` returns the report.
    let raw = http_roundtrip(&addr, &get_request("/shutdown")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 200);
    let report = handle.join().unwrap();
    assert!(report.requests >= hits + 6, "parseable requests all counted: {report:?}");
    assert!(report.hits >= hits + 1, "lookup + batch hits counted: {report:?}");
    assert!(report.misses >= 1, "{report:?}");
    assert!(report.bad_requests >= 2, "malformed + unknown workload/name: {report:?}");
    assert_eq!(report.tuned, 0, "read-only server never tunes: {report:?}");
}

#[test]
fn admission_control_bounces_tune_on_miss_with_429() {
    let (dir, _guard) = tmp_dir("admission");
    // Tuning enabled but an inflight budget of zero: every miss must be
    // bounced immediately instead of queueing behind a search.
    let cfg = HttpConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        max_pending: 8,
        max_inflight_tunes: 0,
        serve: ServeConfig { miss_trials: 4, ..ServeConfig::default() },
        access_log: None,
    };
    let (addr, handle) = start_server(cfg, db_with_gmm(&dir));

    // Hits are unaffected by the zero tune budget.
    let raw = http_roundtrip(&addr, &get_request("/lookup?workload=GMM")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 200);

    for _ in 0..3 {
        let raw = http_roundtrip(&addr, &get_request("/lookup?workload=SFM")).unwrap();
        let (status, body) = split_response(&raw).unwrap();
        assert_eq!(status, 429);
        let j = Json::parse(body.trim()).unwrap();
        assert!(j.get("error").and_then(Json::as_str).unwrap().contains("budget"));
    }

    let raw = http_roundtrip(&addr, &get_request("/shutdown")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 200);
    let report = handle.join().unwrap();
    assert_eq!(report.tune_rejected, 3, "{report:?}");
    assert_eq!(report.tuned, 0, "{report:?}");
    assert!(report.hits >= 1, "{report:?}");
    // 429 is load shedding, not a client error.
    assert_eq!(report.bad_requests, 0, "{report:?}");
}

#[test]
fn metrics_endpoint_and_access_log_observe_hit_miss_and_throttle() {
    let (dir, _guard) = tmp_dir("metrics");
    let log_path = dir.join("access.jsonl");
    // Tune-on-miss enabled with a zero inflight budget: the SFM lookup
    // below is a miss that gets throttled with 429, so one run exercises
    // hit, miss, and throttle counters.
    let cfg = HttpConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        max_pending: 8,
        max_inflight_tunes: 0,
        serve: ServeConfig { miss_trials: 4, ..ServeConfig::default() },
        access_log: Some(log_path.to_string_lossy().into_owned()),
    };
    let (addr, handle) = start_server(cfg, db_with_gmm(&dir));

    // Baseline scrape. The registry is process-global and cumulative, so
    // other tests in this binary may already have moved the counters:
    // every assertion below is a >= delta from this snapshot.
    let raw = http_roundtrip(&addr, &get_request("/metrics")).unwrap();
    assert!(
        String::from_utf8_lossy(&raw).contains("text/plain; version=0.0.4"),
        "exposition content type"
    );
    let (status, body) = split_response(&raw).unwrap();
    assert_eq!(status, 200);
    let before = metaschedule::telemetry::parse_exposition(body).expect("valid exposition");
    let base = |m: &std::collections::BTreeMap<String, f64>, k: &str| m.get(k).copied().unwrap_or(0.0);

    let raw = http_roundtrip(&addr, &get_request("/lookup?workload=GMM")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 200);
    let raw = http_roundtrip(&addr, &get_request("/lookup?workload=SFM")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 429);

    let raw = http_roundtrip(&addr, &get_request("/metrics")).unwrap();
    let (status, body) = split_response(&raw).unwrap();
    assert_eq!(status, 200);
    let after = metaschedule::telemetry::parse_exposition(body).expect("valid exposition");
    assert!(
        base(&after, "serve_requests_total") >= base(&before, "serve_requests_total") + 2.0,
        "requests counted: {after:?}"
    );
    assert!(base(&after, "serve_hits_total") >= base(&before, "serve_hits_total") + 1.0);
    assert!(base(&after, "serve_misses_total") >= base(&before, "serve_misses_total") + 1.0);
    assert!(base(&after, "serve_throttled_total") >= base(&before, "serve_throttled_total") + 1.0);
    // The latency histogram and the db families opened by this server's
    // sharded database are part of the same exposition.
    assert!(body.contains("serve_request_micros_count"), "latency histogram rendered");
    assert!(body.contains("db_commits_total"), "db family rendered");

    let raw = http_roundtrip(&addr, &get_request("/shutdown")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 200);
    let _ = handle.join().unwrap();

    // Structured access log: one JSON object per request, with the
    // hit/miss outcome stamped on /lookup lines.
    let log = std::fs::read_to_string(&log_path).expect("access log written");
    let lines: Vec<Json> = log.lines().map(|l| Json::parse(l).expect("log line is JSON")).collect();
    assert!(lines.len() >= 5, "metrics x2 + lookups x2 + shutdown logged: {}", lines.len());
    for j in &lines {
        assert!(j.get("method").and_then(Json::as_str).is_some());
        assert!(j.get("path").and_then(Json::as_str).is_some());
        assert!(j.get("status").and_then(Json::as_f64).is_some());
        assert!(j.get("micros").and_then(Json::as_f64).is_some());
    }
    assert!(
        lines.iter().any(|j| {
            j.get("path").and_then(Json::as_str).map_or(false, |p| p.contains("GMM"))
                && j.get("hit").and_then(Json::as_bool) == Some(true)
        }),
        "GMM hit logged with hit=true"
    );
    assert!(
        lines.iter().any(|j| j.get("status").and_then(Json::as_f64) == Some(429.0)),
        "throttled request logged with its status"
    );
}

#[test]
fn metrics_endpoint_exposes_graph_fusion_families() {
    let (dir, _guard) = tmp_dir("fusion-metrics");
    // Graph fusion records into the process-global registry that the
    // /metrics endpoint serves — fuse a model in-process, then scrape.
    let g = metaschedule::graph::bert_base_graph();
    let groups = metaschedule::graph::fuse(&g);
    assert!(!groups.is_empty());
    let _ = metaschedule::graph::extract_fused_tasks(&g);

    let (addr, handle) = start_server(read_only_cfg(), db_with_gmm(&dir));
    let raw = http_roundtrip(&addr, &get_request("/metrics")).unwrap();
    let (status, body) = split_response(&raw).unwrap();
    assert_eq!(status, 200);
    let m = metaschedule::telemetry::parse_exposition(body).expect("valid exposition");
    assert!(
        m.get("graph_fused_groups_total").copied().unwrap_or(0.0) >= groups.len() as f64,
        "fused-group counter visible: {m:?}"
    );
    // All four per-class families render, even at zero.
    for kind in ["injective", "reduction", "complex", "opaque"] {
        assert!(
            body.contains(&format!("graph_fusion_kind_total_{kind}")),
            "{kind} family rendered"
        );
    }

    let raw = http_roundtrip(&addr, &get_request("/shutdown")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 200);
    let _ = handle.join().unwrap();
}

#[test]
fn tune_on_miss_commits_and_subsequent_lookups_hit_the_refreshed_shard() {
    let (dir, _guard) = tmp_dir("tune");
    let cfg = HttpConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        max_pending: 8,
        max_inflight_tunes: 1,
        serve: ServeConfig { miss_trials: 4, threads: 1, ..ServeConfig::default() },
        access_log: None,
    };
    let (addr, handle) = start_server(cfg, db_with_gmm(&dir));

    // First SFM lookup misses and tunes (4 trials keeps it fast).
    let raw = http_roundtrip(&addr, &get_request("/lookup?workload=SFM")).unwrap();
    let (status, body) = split_response(&raw).unwrap();
    assert_eq!(status, 200, "{body}");
    let j = Json::parse(body.trim()).unwrap();
    assert_eq!(j.get("hit").and_then(Json::as_bool), Some(false));
    assert_eq!(j.get("tuned").and_then(Json::as_bool), Some(true));

    // The tune republished its shard: the next lookup is a snapshot hit.
    let raw = http_roundtrip(&addr, &get_request("/lookup?workload=SFM")).unwrap();
    let (status, body) = split_response(&raw).unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(body.trim()).unwrap();
    assert_eq!(j.get("hit").and_then(Json::as_bool), Some(true), "{body}");

    let raw = http_roundtrip(&addr, &get_request("/shutdown")).unwrap();
    assert_eq!(split_response(&raw).unwrap().0, 200);
    let report = handle.join().unwrap();
    assert_eq!(report.tuned, 1, "{report:?}");
    assert!(report.hits >= 1, "{report:?}");

    // The commit is durable: reopening the directory shows SFM on disk.
    let db = AnyDb::open(&dir).unwrap();
    let sfm_hash = structural_hash(&(workloads::by_name("SFM").unwrap().build)());
    assert!(db.find_workload(sfm_hash, "cpu").is_some(), "tuned records reached the shard file");
}
