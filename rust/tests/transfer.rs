//! Cross-target transfer priors: the never-truth guarantee (nothing is
//! committed or reported without a destination-target re-measurement),
//! determinism of transfer runs (seed-reproducible, thread-count
//! invariant), the `--no-transfer` escape hatch (pool `None` is
//! byte-identical to the plain database search), and donor
//! incompatibility handling (mismatched rule set / `sim_version`).

use std::collections::HashSet;

use metaschedule::cost_model::GbtCostModel;
use metaschedule::ctx::TuneContext;
use metaschedule::db::{Database, InMemoryDb, TuningRecord};
use metaschedule::search::{EvolutionarySearch, Measurer, SearchConfig, SimMeasurer};
use metaschedule::sim::Target;
use metaschedule::tir::{structural_hash, Program};
use metaschedule::trace::serde::trace_to_text;
use metaschedule::transfer::{TransferConfig, TransferPool};
use metaschedule::workloads;

fn cfg(trials: usize, threads: usize) -> SearchConfig {
    SearchConfig {
        population: 24,
        generations: 3,
        num_trials: trials,
        measure_batch: 8,
        threads,
        ..SearchConfig::default()
    }
}

fn prog() -> Program {
    workloads::matmul(1, 128, 128, 128)
}

/// Tune `prog()` on `target` into `db` (the donor-seeding and the
/// destination runs share this helper).
fn tune_on(
    db: &mut dyn Database,
    target: &Target,
    pool: Option<&TransferPool>,
    trials: usize,
    threads: usize,
    seed: u64,
) -> metaschedule::search::TuneResult {
    let ctx = TuneContext::generic(target.clone());
    let mut model = GbtCostModel::new();
    let mut measurer = SimMeasurer::new(target.clone());
    EvolutionarySearch::new(cfg(trials, threads)).tune_db_transfer(
        &prog(),
        &ctx,
        &mut model,
        &mut measurer,
        db,
        pool,
        seed,
    )
}

/// A database seeded with cpu records for `prog()` (the donor side).
fn cpu_seeded_db(seed: u64) -> InMemoryDb {
    let mut db = InMemoryDb::new();
    let r = tune_on(&mut db, &Target::cpu_avx512(), None, 32, 1, seed);
    assert!(r.trials > 0);
    db
}

fn gpu_pool(db: &dyn Database) -> TransferPool {
    let ctx = TuneContext::generic(Target::gpu());
    TransferPool::collect(
        db,
        structural_hash(&prog()),
        Target::gpu().name,
        Some(Target::cpu_avx512().name),
        &ctx,
        TransferConfig::default(),
    )
}

fn dump(db: &dyn Database, wid: usize) -> Vec<String> {
    db.records_for(wid).iter().map(|r| r.to_json().to_string()).collect()
}

#[test]
fn transfer_injects_priors_and_commits_only_destination_records() {
    let mut db = cpu_seeded_db(1);
    let cpu_wid = db.find_workload(structural_hash(&prog()), "cpu-avx512").unwrap();
    let cpu_records_before = dump(&db, cpu_wid);
    let pool = gpu_pool(&db);
    assert!(!pool.is_empty(), "cpu-seeded db must offer donors");
    assert_eq!(pool.source_targets, vec!["cpu-avx512".to_string()]);

    let r = tune_on(&mut db, &Target::gpu(), Some(&pool), 24, 1, 2);
    assert!(r.transferred_records > 0, "no donor was re-measured");
    assert!(r.transferred_records <= pool.cfg.max_seeds);
    assert_eq!(r.warm_records, 0, "transfer must not masquerade as a native warm start");

    // Everything committed for the gpu workload carries the destination
    // target and the current sim version — donor records are priors,
    // never truth.
    let gpu_wid = db.find_workload(structural_hash(&prog()), "gpu-rtx3070").unwrap();
    let gpu_records = db.records_for(gpu_wid);
    assert_eq!(gpu_records.len(), r.trials, "every trial commits exactly one record");
    for rec in &gpu_records {
        assert_eq!(rec.target, "gpu-rtx3070", "foreign-target record committed");
        assert_eq!(rec.sim_version, metaschedule::sim::SIM_VERSION);
    }
    // The donor side is untouched.
    assert_eq!(dump(&db, cpu_wid), cpu_records_before, "transfer modified the donor records");
}

#[test]
fn transfer_is_deterministic_across_threads_and_repeats() {
    let donor = cpu_seeded_db(3);
    let pool = gpu_pool(&donor);
    assert!(!pool.is_empty());
    let run = |threads: usize| {
        let mut db = donor.clone();
        let r = tune_on(&mut db, &Target::gpu(), Some(&pool), 24, threads, 4);
        let gpu_wid = db.find_workload(structural_hash(&prog()), "gpu-rtx3070").unwrap();
        (r, dump(&db, gpu_wid))
    };
    let (a, recs_a) = run(1);
    for threads in [2, 4] {
        let (b, recs_b) = run(threads);
        assert_eq!(a.best_latency_s, b.best_latency_s, "diverged at {threads} threads");
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.trials, b.trials);
        assert_eq!(a.transferred_records, b.transferred_records);
        assert_eq!(trace_to_text(&a.best_trace), trace_to_text(&b.best_trace));
        assert_eq!(recs_a, recs_b, "committed records diverged with threads");
    }
    // Repeat run from the same snapshot: byte-identical.
    let (c, recs_c) = run(1);
    assert_eq!(a.best_latency_s, c.best_latency_s);
    assert_eq!(a.curve, c.curve);
    assert_eq!(recs_a, recs_c);
}

#[test]
fn no_transfer_is_byte_identical_to_cold_start() {
    // Pool `None` (what the CLI's --no-transfer resolves to) must
    // reproduce the plain tune_db run exactly — same result, same
    // committed bytes.
    let donor = cpu_seeded_db(5);
    let run = |pool: Option<&TransferPool>| {
        let mut db = donor.clone();
        let r = tune_on(&mut db, &Target::gpu(), pool, 24, 1, 6);
        let gpu_wid = db.find_workload(structural_hash(&prog()), "gpu-rtx3070").unwrap();
        (r, dump(&db, gpu_wid))
    };
    let (plain, plain_recs) = {
        let mut db = donor.clone();
        let ctx = TuneContext::generic(Target::gpu());
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(Target::gpu());
        let r = EvolutionarySearch::new(cfg(24, 1)).tune_db(
            &prog(),
            &ctx,
            &mut model,
            &mut measurer,
            &mut db,
            6,
        );
        let gpu_wid = db.find_workload(structural_hash(&prog()), "gpu-rtx3070").unwrap();
        (r, dump(&db, gpu_wid))
    };
    let (none, none_recs) = run(None);
    assert_eq!(plain.best_latency_s, none.best_latency_s);
    assert_eq!(plain.curve, none.curve);
    assert_eq!(none.transferred_records, 0);
    assert_eq!(plain_recs, none_recs, "pool None must be byte-identical to tune_db");
    // And a transfer run really is a *different* search (sanity that the
    // escape hatch is escaping something).
    let pool = gpu_pool(&donor);
    let (with, _) = run(Some(&pool));
    assert!(with.transferred_records > 0);
}

#[test]
fn priors_are_never_reported_without_destination_measurement() {
    // Property over seeds: the reported best is always a latency the
    // destination simulator actually produced for the best program, the
    // curve is monotone, and every committed record's latency set came
    // from the destination target.
    for seed in 0..4u64 {
        let mut db = cpu_seeded_db(10 + seed);
        let pool = gpu_pool(&db);
        let r = tune_on(&mut db, &Target::gpu(), Some(&pool), 16, 1, 20 + seed);
        let mut measurer = SimMeasurer::new(Target::gpu());
        assert_eq!(
            measurer.measure(&r.best_prog),
            Some(r.best_latency_s),
            "seed {seed}: reported best is not a destination measurement"
        );
        for w in r.curve.windows(2) {
            assert!(w[1].1 <= w[0].1, "seed {seed}: curve not monotone");
        }
        let gpu_wid = db.find_workload(structural_hash(&prog()), "gpu-rtx3070").unwrap();
        let recs = db.records_for(gpu_wid);
        let best_committed = recs
            .iter()
            .filter_map(TuningRecord::best_latency)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(best_committed, r.best_latency_s, "seed {seed}: best not committed");
        // No candidate was measured twice (transferred seeds share the
        // dedup set with the evolutionary rounds).
        let hashes: Vec<u64> = recs.iter().map(|rec| rec.cand_hash).collect();
        let unique: HashSet<u64> = hashes.iter().copied().collect();
        assert_eq!(unique.len(), hashes.len(), "seed {seed}: candidate measured twice");
    }
}

/// Build a donor db by hand so incompatible provenance can be injected.
fn crafted_donor(records: Vec<(f64, String, String)>) -> InMemoryDb {
    let ctx = TuneContext::generic(Target::cpu_avx512());
    let designs = ctx.generate(&prog(), 1);
    let sch = metaschedule::trace::replay::replay_fresh(&designs[0].trace, &prog(), 7)
        .expect("design replays");
    let mut db = InMemoryDb::new();
    let wid = db.register_workload("w", structural_hash(&prog()), "cpu-avx512");
    for (i, (lat, sim, rules)) in records.into_iter().enumerate() {
        db.commit_record(TuningRecord {
            workload: wid,
            trace: sch.trace.clone(),
            latencies: vec![lat],
            target: "cpu-avx512".into(),
            seed: 1,
            round: i as u64,
            cand_hash: i as u64 + 1,
            sim_version: sim,
            rule_set: rules,
            objective: String::new(),
        });
    }
    db
}

#[test]
fn incompatible_donors_are_refused_and_counted() {
    let cpu_rules = TuneContext::generic(Target::cpu_avx512()).rule_set().to_string();
    let db = crafted_donor(vec![
        (2e-6, metaschedule::sim::SIM_VERSION.into(), cpu_rules.clone()),
        (1e-6, "sim-v0-retired".into(), cpu_rules.clone()), // stale sim
        (3e-6, metaschedule::sim::SIM_VERSION.into(), "ghost-rule #00000000".into()),
        (4e-6, metaschedule::sim::SIM_VERSION.into(), String::new()), // pre-provenance
    ]);
    let pool = gpu_pool(&db);
    assert_eq!(pool.len(), 1, "exactly one donor is fully compatible");
    assert_eq!(pool.incompatible_sim, 1);
    assert_eq!(pool.incompatible_rules, 2);
    assert_eq!(pool.incompatible(), 3);
}

#[test]
fn warm_start_skips_stale_sim_records() {
    // A stale-version record with an impossibly good latency must not
    // seed best-so-far, the elite pool, or the dedup set — and the run
    // must report how many records it refused.
    let target = Target::cpu_avx512();
    let mut db = InMemoryDb::new();
    let cold = tune_on(&mut db, &target, None, 24, 1, 7);
    assert_eq!(cold.stale_skipped, 0);
    let wid = db.find_workload(structural_hash(&prog()), target.name).unwrap();
    // Inject a stale record claiming a latency nothing real can beat.
    let mut stale = db.query_top_k(wid, 1).remove(0);
    stale.sim_version = "sim-v0-retired".into();
    stale.latencies = vec![1e-15];
    stale.cand_hash = u64::MAX; // unique candidate
    db.commit_record(stale);

    let warm = tune_on(&mut db, &target, None, 16, 1, 7);
    assert!(warm.stale_skipped >= 1, "stale record not counted");
    assert!(
        warm.best_latency_s > 1e-14,
        "stale latency {} adopted as truth",
        warm.best_latency_s
    );
    // The reported best is a real destination measurement.
    let mut measurer = SimMeasurer::new(target.clone());
    assert_eq!(measurer.measure(&warm.best_prog), Some(warm.best_latency_s));
    // Warm start still works off the compatible records.
    assert!(warm.warm_records > 0);
}

#[test]
fn transfer_pool_respects_trial_budget() {
    // Seeding is capped at half the budget: a tiny-budget run must keep
    // most of its trials for the evolutionary rounds.
    let donor = cpu_seeded_db(9);
    let pool = gpu_pool(&donor);
    assert!(!pool.is_empty());
    let mut db = donor.clone();
    let r = tune_on(&mut db, &Target::gpu(), Some(&pool), 8, 1, 11);
    assert!(r.transferred_records <= 4, "seeding overran the cap: {}", r.transferred_records);
    assert!(r.trials <= 8, "budget overrun: {}", r.trials);
}
