//! Registry-equivalence suite: the `TuneContext` redesign must be a pure
//! refactor of the default behaviour.
//!
//! 1. The registry-built default rule set reproduces the old hardcoded
//!    `SpaceComposer::generic` design space *trace for trace* on every
//!    workload x target in the suite (the legacy composition is
//!    reconstructed here from concrete rule types via the public
//!    `SpaceGenerator::new`).
//! 2. A custom rule registered purely through the public API
//!    (`RegistrySet` + `TuneContext::from_specs_in`) grows the space,
//!    tunes deterministically, and shows up in `--explain-space` output
//!    and tuned-record provenance.

use metaschedule::cost_model::GbtCostModel;
use metaschedule::ctx::{RegistrySet, TuneContext};
use metaschedule::db::{Database, InMemoryDb};
use metaschedule::schedule::Schedule;
use metaschedule::search::{EvolutionarySearch, SearchConfig, SimMeasurer};
use metaschedule::sim::{Target, TargetKind};
use metaschedule::space::{
    attempt, AddRfactor, AutoInline, CrossThreadReduction, MultiLevelTiling,
    ParallelVectorizeUnroll, RandomComputeLocation, RuleOutcome, ScheduleRule, SpaceGenerator,
    ThreadBind, UseTensorCore,
};
use metaschedule::tir::structural_hash;
use metaschedule::trace::serde::trace_to_text;
use metaschedule::workloads;

/// The pre-registry hardcoded composition (the old
/// `SpaceComposer::generic` match arms), rebuilt from concrete types.
fn legacy_generic_rules(target: &Target) -> Vec<Box<dyn ScheduleRule>> {
    match target.kind {
        TargetKind::Cpu => vec![
            Box::new(AutoInline::new()),
            Box::new(MultiLevelTiling::cpu()),
            Box::new(AddRfactor::new()),
            Box::new(RandomComputeLocation::new()),
            Box::new(ParallelVectorizeUnroll::new()),
        ],
        TargetKind::Gpu => vec![
            Box::new(AutoInline::new()),
            Box::new(MultiLevelTiling::gpu()),
            Box::new(CrossThreadReduction::new()),
            Box::new(RandomComputeLocation::new()),
            Box::new(ThreadBind::new()),
        ],
    }
}

/// Assert two generated spaces are identical trace-for-trace (and
/// program-for-program).
fn assert_spaces_identical(a: &[Schedule], b: &[Schedule], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: space sizes differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            trace_to_text(&x.trace),
            trace_to_text(&y.trace),
            "{what}: trace {i} differs"
        );
        assert_eq!(
            structural_hash(&x.prog),
            structural_hash(&y.prog),
            "{what}: program {i} differs"
        );
    }
}

#[test]
fn default_rule_set_reproduces_legacy_space_on_every_suite_workload() {
    for target in [Target::cpu_avx512(), Target::gpu()] {
        let registry_ctx = TuneContext::generic(target.clone());
        let legacy = SpaceGenerator::new(legacy_generic_rules(&target), target.clone());
        for w in workloads::suite() {
            let prog = (w.build)();
            let new_space = registry_ctx.generate(&prog, 42);
            let old_space = legacy.generate(&prog, 42);
            assert!(!new_space.is_empty(), "{}: empty space", w.name);
            assert_spaces_identical(
                &new_space,
                &old_space,
                &format!("{} on {}", w.name, target.name),
            );
        }
        // The multi-block fusion workload exercises inlining variants;
        // check a second seed there too.
        let fused = workloads::fused_dense(64, 128, 64);
        for seed in [7, 42] {
            assert_spaces_identical(
                &registry_ctx.generate(&fused, seed),
                &legacy.generate(&fused, seed),
                &format!("fused-dense seed {seed} on {}", target.name),
            );
        }
    }
}

#[test]
fn default_tc_rule_set_reproduces_legacy_tensor_core_insertion() {
    // The old `with_tensor_core` inserted UseTensorCore at index 1.
    let target = Target::gpu();
    let mut rules = legacy_generic_rules(&target);
    rules.insert(1, Box::new(UseTensorCore::wmma()));
    let legacy = SpaceGenerator::new(rules, target.clone());
    let registry_ctx = TuneContext::with_tensor_core(target);
    for prog in [workloads::matmul(1, 128, 128, 128), workloads::fused_dense(64, 128, 64)] {
        assert_spaces_identical(
            &registry_ctx.generate(&prog, 11),
            &legacy.generate(&prog, 11),
            &prog.name,
        );
    }
}

/// A toy expert rule defined *outside* the crate: unroll the innermost
/// loop of reduction blocks, forking unrolled + original. Deterministic
/// (no sampling), so it keeps the search's reproducibility contract.
struct ToyUnroll;

impl ScheduleRule for ToyUnroll {
    fn name(&self) -> &str {
        "toy-unroll"
    }

    fn describe(&self) -> String {
        "test rule: fork an unrolled-innermost variant of reduction blocks".into()
    }

    fn apply(&self, sch: Schedule, block_name: &str, _target: &Target) -> RuleOutcome {
        let is_red = sch
            .prog
            .find_block(block_name)
            .map(|b| sch.prog.block_data(b).is_reduction())
            .unwrap_or(false);
        if !is_red {
            return RuleOutcome::Skip(sch);
        }
        match attempt(&sch, |s| {
            let b = s.get_block(block_name)?;
            let loops = s.get_loops(b)?;
            let last =
                *loops.last().ok_or(metaschedule::schedule::ScheduleError::Unsupported("no loops".into()))?;
            s.unroll(last)
        }) {
            Ok(out) => RuleOutcome::Applied(vec![out, sch]),
            Err(e) => RuleOutcome::Fail(sch, e),
        }
    }
}

#[test]
fn custom_rule_registers_grows_the_space_and_tunes_deterministically() {
    let target = Target::cpu_avx512();
    let prog = workloads::matmul(1, 64, 64, 64);

    // Register purely through the public API and address it from a spec.
    let mut reg = RegistrySet::builtin();
    reg.rules.register("toy-unroll", |_| Box::new(ToyUnroll) as Box<dyn ScheduleRule>);
    let ctx = TuneContext::from_specs_in(
        &reg,
        target.clone(),
        "toy-unroll,default",
        "default",
        "default",
    )
    .expect("custom rule must resolve by name");

    // Provenance label carries the custom rule.
    assert!(ctx.rule_set().starts_with("toy-unroll,auto-inline"), "{}", ctx.rule_set());

    // The space strictly grows vs the generic context (the toy rule
    // forks on the reduction block).
    let generic = TuneContext::generic(target.clone());
    let custom_space = ctx.generate(&prog, 5);
    let generic_space = generic.generate(&prog, 5);
    assert!(
        custom_space.len() > generic_space.len(),
        "custom rule did not grow the space: {} vs {}",
        custom_space.len(),
        generic_space.len()
    );

    // Tuning over the extended space stays deterministic: same seed,
    // same database state => byte-identical results, and every record's
    // provenance names the custom rule.
    let cfg = SearchConfig {
        population: 24,
        generations: 3,
        num_trials: 24,
        measure_batch: 8,
        ..SearchConfig::default()
    };
    let run = || {
        let mut db = InMemoryDb::new();
        let mut model = GbtCostModel::new();
        let mut measurer = SimMeasurer::new(target.clone());
        let r = EvolutionarySearch::new(cfg.clone())
            .tune_db(&prog, &ctx, &mut model, &mut measurer, &mut db, 13);
        let rule_sets: Vec<String> =
            db.records_for(0).iter().map(|rec| rec.rule_set.clone()).collect();
        (r, rule_sets)
    };
    let (a, rules_a) = run();
    let (b, rules_b) = run();
    assert_eq!(a.best_latency_s, b.best_latency_s);
    assert_eq!(a.curve, b.curve);
    assert_eq!(trace_to_text(&a.best_trace), trace_to_text(&b.best_trace));
    assert!(!rules_a.is_empty());
    assert_eq!(rules_a, rules_b);
    for rs in &rules_a {
        assert!(rs.contains("toy-unroll"), "record provenance lost the custom rule: {rs}");
    }

    // And --explain-space surfaces the rule with its counters.
    let explain = ctx.explain();
    assert!(explain.contains("rule toy-unroll:"), "{explain}");
    assert!(explain.contains("applied"), "{explain}");
}

#[test]
fn explain_space_surfaces_structural_failures() {
    // A rule that claims applicability but always errors must be
    // distinguishable from "not applicable" — the bugfix for the old
    // try_transform error swallowing.
    struct AlwaysFails;
    impl ScheduleRule for AlwaysFails {
        fn name(&self) -> &str {
            "always-fails"
        }
        fn apply(&self, sch: Schedule, _block: &str, _t: &Target) -> RuleOutcome {
            RuleOutcome::Fail(
                sch,
                metaschedule::schedule::ScheduleError::Unsupported("deliberate test failure".into()),
            )
        }
    }
    let mut reg = RegistrySet::builtin();
    reg.rules.register("always-fails", |_| Box::new(AlwaysFails) as Box<dyn ScheduleRule>);
    let ctx = TuneContext::from_specs_in(
        &reg,
        Target::cpu_avx512(),
        "always-fails,default",
        "default",
        "default",
    )
    .unwrap();
    let prog = workloads::matmul(1, 32, 32, 32);
    let space = ctx.generate(&prog, 1);
    assert!(!space.is_empty(), "failing rule must pass states through");
    let diag = ctx
        .space()
        .diag()
        .iter()
        .find(|d| d.name() == "always-fails")
        .expect("diag entry");
    assert!(diag.failed() > 0);
    assert_eq!(diag.applied(), 0);
    assert!(diag.errors().iter().any(|e| e.contains("deliberate test failure")));
    let explain = ctx.explain();
    assert!(explain.contains("error: unsupported: deliberate test failure"), "{explain}");
}
